"""Mamba2 SSD (state-space duality) block, pure JAX.

Training/prefill uses the chunked SSD algorithm (arXiv:2405.21060 listing 1):
intra-chunk dual (quadratic-in-chunk, matmul-heavy → MXU friendly) plus an
inter-chunk linear recurrence via lax.scan. Decode uses the O(1) recurrent
step on a (B, H, P, N) state cache.

Single B/C group (G=1). Head layout: d_inner = expand*d_model = H*P.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import SSMConfig


def ssm_dims(d_model: int, scfg: SSMConfig):
    d_inner = scfg.expand * d_model
    n_heads = d_inner // scfg.head_dim
    return d_inner, n_heads


def ssm_params_shape(d_model: int, scfg: SSMConfig):
    d_inner, n_heads = ssm_dims(d_model, scfg)
    conv_ch = d_inner + 2 * scfg.d_state
    return {
        "in_proj": (d_model, 2 * d_inner + 2 * scfg.d_state + n_heads),
        "conv_w": (scfg.d_conv, conv_ch),
        "conv_b": (conv_ch,),
        "dt_bias": (n_heads,),
        "A_log": (n_heads,),
        "D": (n_heads,),
        "norm_scale": (d_inner,),
        "out_proj": (d_inner, d_model),
    }


def _segsum(x: jax.Array) -> jax.Array:
    """x: (..., Q) → (..., Q, Q) with S[i,j] = sum_{k=j+1..i} x_k, -inf i<j."""
    q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    s = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, s, -jnp.inf)


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv. x: (B,L,C), w: (K,C)."""
    k, c = w.shape
    out = lax.conv_general_dilated(
        x, w[:, None, :].astype(x.dtype),
        window_strides=(1,), padding=[(k - 1, 0)],
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=c)
    return out + b.astype(x.dtype)


def ssd_chunked(x, dt, A, B_mat, C_mat, chunk: int):
    """Chunked SSD scan.

    x: (B,L,H,P); dt: (B,L,H) (post-softplus); A: (H,) negative;
    B_mat/C_mat: (B,L,N). Returns (B,L,H,P) and final state (B,H,P,N).
    """
    b, l, h, p = x.shape
    n = B_mat.shape[-1]
    q = min(chunk, l)
    pad = (-l) % q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad)) + ((0, 0),))
        B_mat = jnp.pad(B_mat, ((0, 0), (0, pad), (0, 0)))
        C_mat = jnp.pad(C_mat, ((0, 0), (0, pad), (0, 0)))
    nc = x.shape[1] // q

    f32 = jnp.float32
    xb = (x * dt[..., None].astype(x.dtype)).reshape(b, nc, q, h, p)
    Bc = B_mat.reshape(b, nc, q, n)
    Cc = C_mat.reshape(b, nc, q, n)
    dA = (dt.astype(f32) * A.astype(f32)).reshape(b, nc, q, h)  # (B,nc,Q,H)
    dA = dA.transpose(0, 1, 3, 2)                               # (B,nc,H,Q)
    dA_cs = jnp.cumsum(dA, axis=-1)

    # intra-chunk (dual / quadratic) term
    L = jnp.exp(_segsum(dA))                                    # (B,nc,H,Q,Q)
    scores = jnp.einsum("bcqn,bcsn->bcqs", Cc.astype(f32), Bc.astype(f32))
    Y_diag = jnp.einsum("bcqs,bchqs,bcshp->bcqhp",
                        scores, L, xb.astype(f32))

    # per-chunk input → state contribution
    decay_states = jnp.exp(dA_cs[..., -1:] - dA_cs)             # (B,nc,H,Q)
    states = jnp.einsum("bcsn,bchs,bcshp->bchpn",
                        Bc.astype(f32), decay_states, xb.astype(f32))

    # inter-chunk recurrence over nc chunks
    chunk_decay = jnp.exp(dA_cs[..., -1])                       # (B,nc,H)

    def step(h_prev, inp):
        st, dec = inp                                           # (B,H,P,N),(B,H)
        h_new = h_prev * dec[..., None, None] + st
        return h_new, h_prev                                    # emit state BEFORE chunk

    init = jnp.zeros((b, h, p, n), f32)
    final_state, prev_states = lax.scan(
        step, init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)          # (B,nc,H,P,N)

    # inter-chunk (off-diagonal) output term
    state_decay = jnp.exp(dA_cs)                                # (B,nc,H,Q)
    Y_off = jnp.einsum("bcqn,bchpn,bchq->bcqhp",
                       Cc.astype(f32), prev_states, state_decay)

    y = (Y_diag + Y_off).reshape(b, nc * q, h, p)[:, :l]
    return y.astype(x.dtype), final_state


class SSMCache(NamedTuple):
    conv: jax.Array     # (B, d_conv-1, conv_channels)
    state: jax.Array    # (B, H, P, N) float32


def init_ssm_cache(batch: int, d_model: int, scfg: SSMConfig,
                   dtype=jnp.bfloat16) -> SSMCache:
    d_inner, n_heads = ssm_dims(d_model, scfg)
    conv_ch = d_inner + 2 * scfg.d_state
    return SSMCache(
        conv=jnp.zeros((batch, scfg.d_conv - 1, conv_ch), dtype),
        state=jnp.zeros((batch, n_heads, scfg.head_dim, scfg.d_state),
                        jnp.float32))


def _split_xbc(xbc, d_inner, d_state):
    x = xbc[..., :d_inner]
    B_mat = xbc[..., d_inner:d_inner + d_state]
    C_mat = xbc[..., d_inner + d_state:]
    return x, B_mat, C_mat


def ssm_block(x_in: jax.Array, params, scfg: SSMConfig):
    """Full Mamba2 block forward. x_in: (B,L,d) → (B,L,d)."""
    from repro.models.layers import rmsnorm
    b, l, d = x_in.shape
    d_inner, n_heads = ssm_dims(d, scfg)
    n = scfg.d_state

    proj = jnp.einsum("bld,de->ble", x_in, params["in_proj"])
    z = proj[..., :d_inner]
    xbc = proj[..., d_inner:d_inner + d_inner + 2 * n]
    dt = proj[..., -n_heads:]

    xbc = jax.nn.silu(_causal_conv(xbc, params["conv_w"], params["conv_b"]))
    xs, B_mat, C_mat = _split_xbc(xbc, d_inner, n)
    xs = xs.reshape(b, l, n_heads, scfg.head_dim)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))

    y, _ = ssd_chunked(xs, dt, A, B_mat, C_mat, scfg.chunk)
    y = y + xs * params["D"].astype(xs.dtype)[None, None, :, None]
    y = y.reshape(b, l, d_inner)
    y = rmsnorm(y * jax.nn.silu(z), params["norm_scale"])
    return jnp.einsum("ble,ed->bld", y, params["out_proj"])


def ssm_block_decode(x_in: jax.Array, params, scfg: SSMConfig,
                     cache: SSMCache):
    """Single-token recurrent step. x_in: (B,1,d) → (B,1,d), new cache."""
    from repro.models.layers import rmsnorm
    b, _, d = x_in.shape
    d_inner, n_heads = ssm_dims(d, scfg)
    n = scfg.d_state
    p = scfg.head_dim

    proj = jnp.einsum("bld,de->ble", x_in, params["in_proj"])[:, 0]  # (B,E)
    z = proj[..., :d_inner]
    xbc = proj[..., d_inner:d_inner + d_inner + 2 * n]
    dt = proj[..., -n_heads:]

    # rolling conv state
    win = jnp.concatenate([cache.conv, xbc[:, None, :]], axis=1)  # (B,K,C)
    conv_out = jnp.einsum("bkc,kc->bc", win.astype(jnp.float32),
                          params["conv_w"].astype(jnp.float32))
    xbc = jax.nn.silu(conv_out + params["conv_b"].astype(jnp.float32)
                      ).astype(x_in.dtype)
    new_conv = win[:, 1:]

    xs, B_mat, C_mat = _split_xbc(xbc, d_inner, n)
    xs = xs.reshape(b, n_heads, p)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))  # (B,H)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))

    decay = jnp.exp(dt * A)                                       # (B,H)
    upd = (dt[..., None] * xs.astype(jnp.float32))[..., None] \
        * B_mat.astype(jnp.float32)[:, None, None, :]             # (B,H,P,N)
    h_new = cache.state * decay[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", h_new, C_mat.astype(jnp.float32))
    y = y.astype(xs.dtype) + xs * params["D"].astype(xs.dtype)[None, :, None]
    y = y.reshape(b, d_inner)
    y = rmsnorm((y * jax.nn.silu(z))[:, None, :], params["norm_scale"])
    out = jnp.einsum("ble,ed->bld", y, params["out_proj"])
    return out, SSMCache(conv=new_conv, state=h_new)
