"""DistSim events: deduplicated units of profiling (paper §3.2, §4.1).

An ``Event`` is an *identical* piece of work performed by possibly many
devices / many microbatches — the key to the paper's Observation 1
(profiling redundancy): it's profiled ONCE. Identity is structural:
(kind, op descriptor, sharded shapes, participant count, intra/inter
scope). Two replicas computing the same sharded layer hash to the same
event; so do all microbatches of a pipeline stage.

``Strategy`` captures the hybrid-parallelism configuration "xM xP xD"
from the paper plus our beyond-paper axes (ZeRO-1, EP).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.configs.base import ArchConfig
from repro.core.modelgraph import (GEMM, LayerSpec, build_decode_graph,
                                   build_graph)
from repro.core.scenario import TRAIN, Scenario


@dataclasses.dataclass(frozen=True)
class Strategy:
    """Hybrid distributed training strategy ("xM xP xD")."""
    mp: int = 1                   # tensor/model parallel degree
    pp: int = 1                   # pipeline parallel degree
    dp: int = 1                   # data parallel degree
    microbatches: int = 1         # per-replica microbatch count M
    schedule: str = "1f1b"        # gpipe | 1f1b (Dapple) | interleaved
    zero1: bool = False           # shard optimizer state over dp
    # gradient compression ratio on the DP sync (1.0 = off; 0.25 = int8
    # + scales — see repro.train.compression). A DistSim what-if knob.
    grad_compress: float = 1.0
    # interleaved: virtual stages per device (Megatron interleaved 1F1B)
    vpp: int = 1

    @property
    def devices(self) -> int:
        return self.mp * self.pp * self.dp

    def label(self) -> str:
        return f"{self.mp}M{self.pp}P{self.dp}D"

    def microbatch_size(self, global_batch: int) -> int:
        """Per-microbatch sample count with the ``max(1, ...)`` floor.
        The ONE definition of the microbatch-derivation formula —
        ``DistSim.microbatch`` and ``validate.BuildCache`` both call
        this, so the cache key and the simulator can't drift apart
        (the drift class ``profiling_report()`` once suffered from)."""
        return max(1, global_batch // (self.dp * self.microbatches))

    # ---- JSON round-trip (repro.validate reports, goldens) ----
    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict) -> "Strategy":
        from repro.core.serde import dataclass_from_dict
        return dataclass_from_dict(cls, d)


@dataclasses.dataclass(frozen=True)
class Event:
    kind: str                       # compute | collective | p2p | hbm
    # display-only: equality/hashing is the STRUCTURAL signature
    # (kind, op, sharded shapes, participants, scope) — the paper's
    # unique-event identity. Two stages' p2p sends of the same payload
    # are ONE profiling event even though their labels differ.
    name: str = dataclasses.field(compare=False)
    gemms: Tuple[GEMM, ...] = ()    # compute: sharded GEMM dims
    coll_op: str = ""               # collective: all_reduce | all_gather | ...
    nbytes: float = 0.0             # collective/p2p payload (full tensor)
    n_dev: int = 1                  # collective participant count
    scope: str = "intra"            # intra | inter (island)

    @property
    def flops(self) -> float:
        return sum(g.flops for g in self.gemms)


@dataclasses.dataclass
class ComposedEvent:
    """Paper §3.2: one strategy level's bundle of events.

    For MP modeling, a layer's forward = [compute event, TP all-reduce,
    (EP all-to-all)]. Times are attached later by the profiler.
    """
    name: str
    events: List[Event]

    def total(self, profile: Dict[Event, float]) -> float:
        return sum(profile[e] for e in self.events)


# --------------------------------------------------------------------------
# MP-level modeling (paper §4.3 "Model Parallelism Modeling")
# --------------------------------------------------------------------------

def _shard_gemms(spec: LayerSpec, mp: int) -> Tuple[GEMM, ...]:
    return tuple(g.shard(mp, ax) for g, ax in zip(spec.gemms,
                                                  spec.shard_axes))


def _scope(ranks_span: int, devices_per_island: int) -> str:
    return "intra" if ranks_span <= devices_per_island else "inter"


def layer_composed_events(spec: LayerSpec, mp: int, devices_per_island: int,
                          phase: str) -> ComposedEvent:
    """ComposedEvent for one layer's fwd or bwd under MP=mp."""
    assert phase in ("fwd", "bwd")
    mult = 1 if phase == "fwd" else 2
    gemms = _shard_gemms(spec, mp) if spec.mp_shardable else spec.gemms
    if mult == 2:
        gemms = gemms + gemms           # dgrad + wgrad, same dims class
    events = [Event(kind="compute",
                    name=f"{spec.name}:{phase}:mp{mp}",
                    gemms=gemms)]
    if mp > 1 and spec.tp_allreduce_bytes:
        events.append(Event(
            kind="collective", name=f"{spec.name}:{phase}:tp_ar:mp{mp}",
            coll_op="all_reduce", nbytes=spec.tp_allreduce_bytes,
            n_dev=mp, scope=_scope(mp, devices_per_island)))
    if mp > 1 and spec.ep_alltoall_bytes:
        events.append(Event(
            kind="collective", name=f"{spec.name}:{phase}:ep_a2a:mp{mp}",
            coll_op="all_to_all", nbytes=spec.ep_alltoall_bytes / mp,
            n_dev=mp, scope=_scope(mp, devices_per_island)))
    if spec.kv_read_bytes:
        # decode: KV-cache / SSM-state read from HBM (sharded with the
        # KV heads under TP)
        shard = mp if spec.mp_shardable else 1
        events.append(Event(
            kind="hbm", name=f"{spec.name}:{phase}:kv_read:mp{mp}",
            nbytes=spec.kv_read_bytes / shard))
    return ComposedEvent(f"{spec.name}:{phase}", events)


# --------------------------------------------------------------------------
# stage partitioning (PP level input)
# --------------------------------------------------------------------------

@dataclasses.dataclass
class Stage:
    index: int
    layers: List[LayerSpec]         # flattened (one entry per actual layer)
    fwd: Optional[ComposedEvent] = None
    bwd: Optional[ComposedEvent] = None
    # decode: payload the LAST stage feeds back to stage 0 between
    # autoregressive steps (sampled token ids). 0.0 for train/prefill.
    # A class-level default so stages unpickled from pre-scenario
    # stores read 0.0 via the class attribute.
    feedback_bytes: float = 0.0

    @property
    def param_bytes(self) -> float:
        return sum(l.param_bytes for l in self.layers)

    @property
    def boundary_act_bytes(self) -> float:
        return self.layers[-1].act_bytes if self.layers else 0.0


def flatten_layers(cfg: ArchConfig, microbatch: int, seq: int,
                   scenario: Scenario = TRAIN,
                   layers: Optional[List[LayerSpec]] = None
                   ) -> List[LayerSpec]:
    """Flatten the model into one entry per actual layer.

    ``scenario`` selects the layer graph (train/prefill share the full-
    sequence forward graph; decode builds the seq=1 graph with KV-read
    terms). An explicit ``layers`` list overrides the generated graph —
    the hook for heterogeneous per-layer configurations (non-uniform
    widths, per-layer seq) that no ``ArchConfig`` template expresses.
    """
    if layers is None:
        if scenario.kind == "decode":
            layers = build_decode_graph(cfg, microbatch,
                                        scenario.kv_len(seq))
        else:
            layers = build_graph(cfg, microbatch, seq)
    out: List[LayerSpec] = []
    for spec in layers:
        out.extend([spec] * spec.count)
    return out


def partition_stages(layers: List[LayerSpec], pp: int,
                     balanced: bool = False) -> List[Stage]:
    """Balance stages by forward FLOPs (greedy prefix split).

    With ``balanced=True`` every stage is guaranteed non-empty whenever
    ``len(layers) >= pp`` (the greedy split is forced once exactly one
    layer per remaining stage is left). The default keeps the historic
    behaviour — tiny models may pad trailing empty stages — because
    existing training goldens bake that in.
    """
    total = sum(l.fwd_flops for l in layers) or 1.0
    target = total / pp
    stages: List[Stage] = []
    cur: List[LayerSpec] = []
    acc = 0.0
    idx = 0
    for i, l in enumerate(layers):
        cur.append(l)
        acc += l.fwd_flops
        remaining_layers = len(layers) - i - 1
        remaining_stages = pp - idx - 1
        force = balanced and remaining_layers == remaining_stages
        if ((acc >= target or force) and remaining_stages > 0
                and remaining_layers >= remaining_stages):
            stages.append(Stage(idx, cur))
            idx, cur, acc = idx + 1, [], 0.0
    stages.append(Stage(idx, cur))
    while len(stages) < pp:                       # degenerate tiny models
        stages.append(Stage(len(stages), []))
    return stages


def build_stage_events(cfg: ArchConfig, strat: Strategy, microbatch: int,
                       seq: int, devices_per_island: int) -> List[Stage]:
    layers = flatten_layers(cfg, microbatch, seq)
    stages = partition_stages(layers, strat.pp)
    for st in stages:
        fwd_events: List[Event] = []
        bwd_events: List[Event] = []
        for l in st.layers:
            fwd_events.extend(layer_composed_events(
                l, strat.mp, devices_per_island, "fwd").events)
            bwd_events.extend(layer_composed_events(
                l, strat.mp, devices_per_island, "bwd").events)
        st.fwd = ComposedEvent(f"stage{st.index}:fwd", fwd_events)
        st.bwd = ComposedEvent(f"stage{st.index}:bwd", bwd_events)
    return stages


# --------------------------------------------------------------------------
# event universe + dedup accounting (Table 3 metric)
# --------------------------------------------------------------------------

def stage_event_set(stages: List[Stage]) -> "set[Event]":
    """Unique compute/comm events across a stage list — the profiling
    working set a candidate strategy adds to a shared cache."""
    out: set = set()
    for st in stages:
        if st.fwd is not None:
            out.update(st.fwd.events)
        if st.bwd is not None:
            out.update(st.bwd.events)
    return out


def stage_signature(stages: List[Stage]) -> Tuple:
    """Structural identity of a positions list — exactly what an
    :class:`repro.core.engine.EventFlowEngine` reads from it: the
    per-position fwd/bwd event tuples (structural ``Event`` identity,
    names excluded) plus the boundary/param byte counts. Two lists with
    equal signatures build bit-identical engines, so ``DistSim.engine``
    keys its cache on this rather than on list object identity (which
    both missed equal-content rebuilds and silently reused engines for
    mutated lists)."""
    return tuple(
        (tuple(st.fwd.events) if st.fwd is not None else (),
         tuple(st.bwd.events) if st.bwd is not None else (),
         st.boundary_act_bytes, st.param_bytes,
         getattr(st, "feedback_bytes", 0.0))
        for st in stages)


def unique_events(stages: List[Stage], strat: Strategy,
                  devices_per_island: int) -> Dict[Event, int]:
    """All unique events with their total instance counts across the
    cluster & microbatches — the dedup ratio drives Table 3."""
    counts: Dict[Event, int] = {}

    def add(e: Event, n: int):
        counts[e] = counts.get(e, 0) + n

    m = strat.microbatches
    for st in stages:
        for e in st.fwd.events + st.bwd.events:
            add(e, m * strat.mp * strat.dp)
        if st.index < len(stages) - 1:
            span = strat.mp                      # stage boundary rank stride
            add(Event(kind="p2p", name=f"p2p:s{st.index}",
                      nbytes=st.boundary_act_bytes,
                      scope=_scope(span + 1, devices_per_island)),
                2 * m * strat.mp * strat.dp)     # fwd act + bwd grad
        if strat.dp > 1:
            add(Event(kind="collective", name=f"dp_ar:s{st.index}",
                      coll_op="all_reduce",
                      nbytes=st.param_bytes / max(1, strat.mp),
                      n_dev=strat.dp,
                      scope=_scope(strat.dp * strat.pp * strat.mp,
                                   devices_per_island)),
                strat.mp * strat.dp)
    return counts
