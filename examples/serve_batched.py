"""Batched serving demo: continuous decode over a request batch.

Builds a reduced model, prefills each request's prompt through the
decode path, then generates with greedy sampling while tracking
per-token latency — the `serve_step` exercised by the decode/long
dry-run cells, on CPU at smoke scale.

    PYTHONPATH=src python examples/serve_batched.py --requests 4 --gen 32
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_config, smoke_config
from repro.models.api import build_model
from repro.models.layers import ModelOptions


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2_1_5b")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    cfg = smoke_config(get_config(args.arch))
    opts = ModelOptions(dtype=jnp.float32, remat=False)
    api = build_model(cfg, opts)
    key = jax.random.PRNGKey(0)
    params = api.init(key)

    b = args.requests
    max_seq = args.prompt_len + args.gen
    prompts = jax.random.randint(key, (b, args.prompt_len), 1, cfg.vocab,
                                 jnp.int32)
    cache = api.init_cache(b, max_seq)
    step = jax.jit(api.decode_step)

    # prefill (token-by-token through the decode path; a production
    # server uses the prefill kernel — see launch/dryrun.py prefill cells)
    t0 = time.perf_counter()
    logits = None
    for t in range(args.prompt_len):
        logits, cache = step(params, cache,
                             {"tokens": prompts[:, t:t + 1]})
    prefill_s = time.perf_counter() - t0

    # greedy generation
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out = [tok]
    lat = []
    for _ in range(args.gen - 1):
        t0 = time.perf_counter()
        logits, cache = step(params, cache, {"tokens": tok})
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        tok.block_until_ready()
        lat.append(time.perf_counter() - t0)
        out.append(tok)

    gen = jnp.concatenate(out, axis=1)
    import numpy as np
    lat = np.array(lat) * 1e3
    print(f"arch={cfg.name} requests={b} prompt={args.prompt_len} "
          f"gen={args.gen}")
    print(f"prefill: {prefill_s*1e3:.1f} ms total")
    print(f"decode : p50={np.percentile(lat,50):.1f} ms/tok  "
          f"p99={np.percentile(lat,99):.1f} ms/tok  "
          f"throughput={b/ (lat.mean()/1e3):.0f} tok/s")
    print("sample tokens:", np.asarray(gen[0][:16]))


if __name__ == "__main__":
    main()
