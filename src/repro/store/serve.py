"""Simulator-as-a-service: strategy queries over a warm ProfileStore.

The production framing of the paper's unique-event dedup: a
capacity-planning service answering "(model, strategy, cluster) →
predicted batch time, memory headroom, utilization" at interactive
latency. All heavy state — profiled event times and engine builds —
comes from a shared :class:`~repro.store.profile_store.ProfileStore`,
so a warm server performs ZERO provider evaluations (asserted in
``tests/test_store.py``); queries only pay schedule construction and
one array evaluation.

The batch path scores every queried strategy of a cluster in ONE
:class:`~repro.core.megabatch.MegaBatch` array call, so answering a
thousand queries costs one padded ``(steps, K)`` program per cluster —
batch times stay bit-identical to per-query ``DistSim.simulate()``.

    server = DistSim.serve("/var/distsim/store")
    ans = server.answer(ServeQuery("gpt2_345m", Strategy(pp=2, dp=2,
                                   microbatches=4)))
    answers = server.answer_batch(queries)      # mega-batch scored
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence

from repro.configs.base import get_config, smoke_config
from repro.core.costmodel import A40_CLUSTER, CLUSTERS, ClusterSpec
from repro.core.events import Strategy
from repro.core.megabatch import MegaBatch
from repro.core.modelgraph import kv_cache_bytes
from repro.core.perturb import Perturbation, perturbation_from_dict
from repro.core.profiler import AnalyticalProvider
from repro.core.scenario import TRAIN, Scenario, scenario_from_dict
from repro.search.prune import HBM_BUDGET, estimate_memory
from repro.store.persistent import PersistentBuildCache
from repro.store.profile_store import ProfileStore, open_store


@dataclasses.dataclass(frozen=True)
class ServeQuery:
    """One capacity-planning question — training by default, serving
    when ``scenario`` is a :class:`~repro.core.scenario.Prefill` or
    :class:`~repro.core.scenario.Decode` (then ``global_batch`` is the
    concurrent request count and tokens/sec is decode throughput)."""
    arch: str
    strategy: Strategy
    global_batch: int = 16
    seq: int = 512
    smoke: bool = False                    # reduce arch via smoke_config
    cluster: str = A40_CLUSTER.name       # registry name
    scenario: Scenario = TRAIN
    # degraded-fleet what-if: a straggler plane applied at predict
    # time (run-level only — builds/store addresses never key on it)
    perturb: Optional[Perturbation] = None

    def to_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d["strategy"] = self.strategy.to_dict()
        d["scenario"] = self.scenario.to_dict()
        # the scenario-key pattern: an absent axis is OMITTED, so every
        # pre-perturb serialized query/report stays byte-identical
        if self.perturb is None:
            del d["perturb"]
        else:
            d["perturb"] = self.perturb.to_dict()
        return d

    @classmethod
    def from_dict(cls, d: Dict) -> "ServeQuery":
        d = dict(d)
        d["strategy"] = Strategy.from_dict(d["strategy"])
        d["scenario"] = scenario_from_dict(d.get("scenario"))
        d["perturb"] = perturbation_from_dict(d.get("perturb"))
        from repro.core.serde import dataclass_from_dict
        return dataclass_from_dict(cls, d)


@dataclasses.dataclass
class ServeAnswer:
    """The service's reply: predicted iteration economics + memory."""
    query: ServeQuery
    batch_time: float           # bit-identical to DistSim.simulate()
    throughput_iters: float
    throughput_tokens: float
    mem_bytes: float            # estimated per-device HBM footprint
    hbm_headroom: float         # budgeted HBM minus footprint
    feasible: bool              # fits in the HBM budget
    utilization_mean: float     # mean busy fraction across devices
    bubble_fraction: float
    kv_cache_bytes: float = 0.0  # per-device KV/SSM state (decode only)

    def to_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d["query"] = self.query.to_dict()
        return d


class StrategyServer:
    """Query front-end over one store (``DistSim.serve(store)``).

    Holds one provider + :class:`PersistentBuildCache` per cluster
    (created lazily on first query for that cluster, which loads the
    persisted events). Repeat queries reuse in-memory engines and the
    compiled mega-batch program; newly-profiled events (cold entries)
    are flushed back to the store after every batch, so the store warms
    monotonically under live traffic.
    """

    _PROGRAM_MEMO_MAX = 8

    def __init__(self, store, clusters: Optional[Sequence[ClusterSpec]]
                 = None, provider_factory=AnalyticalProvider,
                 backend: str = "auto"):
        self.store: ProfileStore = open_store(store)
        specs = list(clusters) if clusters is not None \
            else list(CLUSTERS.values())
        self.clusters: Dict[str, ClusterSpec] = {c.name: c for c in specs}
        self.provider_factory = provider_factory
        self.backend = backend
        self._caches: Dict[str, PersistentBuildCache] = {}
        self._programs: "OrderedDict" = OrderedDict()
        self.queries_answered = 0

    # ---- plumbing ----

    def _cache_for(self, cluster_name: str) -> PersistentBuildCache:
        bc = self._caches.get(cluster_name)
        if bc is None:
            try:
                spec = self.clusters[cluster_name]
            except KeyError:
                raise ValueError(
                    f"unknown cluster {cluster_name!r}; served: "
                    f"{sorted(self.clusters)}") from None
            bc = PersistentBuildCache(self.provider_factory(spec),
                                      self.store)
            self._caches[cluster_name] = bc
        return bc

    @staticmethod
    def _resolve_cfg(q: ServeQuery):
        cfg = get_config(q.arch)
        return smoke_config(cfg) if q.smoke else cfg

    # ---- the query surface ----

    def answer(self, query: ServeQuery) -> ServeAnswer:
        return self.answer_batch([query])[0]

    def answer_batch(self, queries: Sequence[ServeQuery]
                     ) -> List[ServeAnswer]:
        """Answer all queries, one mega-batch array call per distinct
        (cluster, perturbation) group, answers returned in query
        order. Perturbed queries share the unperturbed queries'
        engines and store entries — only the compiled program differs
        (the straggler plane scales profiled means at compile time)."""
        queries = list(queries)
        by_group: "OrderedDict" = OrderedDict()
        for i, q in enumerate(queries):
            by_group.setdefault((q.cluster, q.perturb), []).append(i)

        answers: List[Optional[ServeAnswer]] = [None] * len(queries)
        for (cname, perturb), idxs in by_group.items():
            bc = self._cache_for(cname)
            spec = self.clusters[cname]
            budget = spec.chip.hbm_bytes * HBM_BUDGET
            engines = []
            meta = []
            for i in idxs:
                q = queries[i]
                cfg = self._resolve_cfg(q)
                sc = q.scenario
                micro = sc.microbatch_size(q.strategy, q.global_batch)
                mem = estimate_memory(cfg, q.strategy, micro, q.seq, sc)
                kv = 0.0
                if sc.kind == "decode":
                    kv = kv_cache_bytes(cfg, micro, sc.kv_len(q.seq)) \
                        / (q.strategy.mp * q.strategy.pp)
                eng = bc.engine_for_cfg(cfg, q.strategy,
                                        q.global_batch, q.seq, sc)
                meta.append((i, q, mem, budget - mem, kv))
                engines.append(eng)

            # engine objects are stable across repeat queries (the
            # build cache returns incumbents), so a repeat batch reuses
            # the compiled program and pays only the array eval
            key = (cname, perturb, tuple(id(e) for e in engines))
            mb = self._programs.get(key)
            if mb is None:
                mb = MegaBatch(engines, perturb=perturb)
                self._programs[key] = mb
                while len(self._programs) > self._PROGRAM_MEMO_MAX:
                    self._programs.popitem(last=False)
            pred = mb.predict(self.backend)

            for lane, (i, q, mem, headroom, kv) in enumerate(meta):
                bt = float(pred.batch_times[lane])
                bubble = float(pred.bubble_fractions[lane])
                answers[i] = ServeAnswer(
                    query=q, batch_time=bt,
                    throughput_iters=1.0 / bt if bt else 0.0,
                    throughput_tokens=(
                        q.scenario.tokens(q.global_batch, q.seq) / bt
                        if bt else 0.0),
                    mem_bytes=mem, hbm_headroom=headroom,
                    feasible=headroom > 0,
                    utilization_mean=1.0 - bubble,
                    bubble_fraction=bubble, kv_cache_bytes=kv)
            bc.flush()          # persist any cold-profiled events
        self.queries_answered += len(queries)
        assert all(a is not None for a in answers)
        return answers

    # ---- accounting ----

    def snapshot(self) -> Dict:
        """Per-cluster provider + build-cache accounting, plus store
        stats — the 'zero evaluations on a warm store' evidence."""
        out: Dict = {"queries_answered": self.queries_answered,
                     "store": self.store.snapshot(), "clusters": {}}
        for name, bc in self._caches.items():
            ps = bc.provider.stats
            out["clusters"][name] = {
                "evaluations": ps.evaluations, "hits": ps.hits,
                "unique_events": bc.provider.cache_size,
                "builds": bc.stats.to_dict(),
            }
        return out
