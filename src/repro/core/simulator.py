"""DistSim top-level API (paper Fig. 6).

    sim = DistSim(cfg, strategy, global_batch=16, seq=512)
    result = sim.predict()          # deduped-event timeline (the model)
    actual = sim.replay(seed=0)     # discrete-event oracle ("actual run")

``predict`` uses each unique event's profiled mean once — the paper's
construction. ``replay`` executes every per-device event instance with
profiling jitter, straggler and clock effects — our stand-in for the real
16-GPU cluster (see DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.configs.base import ArchConfig
from repro.core.costmodel import V5E_POD
from repro.core.events import (Stage, Strategy, build_stage_events,
                               unique_events)
from repro.core.hierarchy import build_positions, construct_timeline
from repro.core.profiler import (AnalyticalProvider, Provider,
                                 profile_events, profiling_cost)
from repro.core.timeline import Timeline


@dataclasses.dataclass
class SimResult:
    timeline: Timeline
    batch_time: float
    throughput_iters: float
    throughput_tokens: float
    utilization: Dict[int, float]
    bubble_fraction: float


class DistSim:
    def __init__(self, cfg: ArchConfig, strategy: Strategy,
                 global_batch: int, seq: int,
                 provider: Optional[Provider] = None):
        self.cfg = cfg
        self.strategy = strategy
        self.global_batch = global_batch
        self.seq = seq
        self.provider = provider or AnalyticalProvider(V5E_POD)
        if global_batch % (strategy.dp * strategy.microbatches):
            raise ValueError(
                f"global_batch {global_batch} not divisible by "
                f"dp*microbatches = {strategy.dp * strategy.microbatches}")

    # ---- the performance model ----
    def predict(self, positions: Optional[List[Stage]] = None) -> SimResult:
        tl = construct_timeline(self.cfg, self.strategy, self.global_batch,
                                self.seq, self.provider, positions=positions)
        return self._result(tl)

    # ---- the "actual run" oracle ----
    def replay(self, seed: int = 0, jitter_sigma: float = 0.025,
               straggler_sigma: float = 0.0,
               clock_sigma: float = 0.0,
               positions: Optional[List[Stage]] = None) -> SimResult:
        tl = construct_timeline(self.cfg, self.strategy, self.global_batch,
                                self.seq, self.provider,
                                jitter_sigma=jitter_sigma,
                                straggler_sigma=straggler_sigma,
                                clock_sigma=clock_sigma, seed=seed,
                                positions=positions)
        return self._result(tl)

    # ---- conformance hook (repro.validate) ----
    def predict_and_replay(self, seeds=(0,), jitter_sigma: float = 0.025,
                           straggler_sigma: float = 0.0,
                           clock_sigma: float = 0.0):
        """One prediction plus a replay per seed, all sharing a single
        positions build — the per-cell unit of the accuracy sweep.
        Returns ``(pred, [replay_0, ...])``."""
        positions = self.positions()
        pred = self.predict(positions=positions)
        replays = [self.replay(seed=s, jitter_sigma=jitter_sigma,
                               straggler_sigma=straggler_sigma,
                               clock_sigma=clock_sigma,
                               positions=positions)
                   for s in seeds]
        return pred, replays

    # ---- search-engine hooks ----
    def microbatch(self) -> int:
        return max(1, self.global_batch
                   // (self.strategy.dp * self.strategy.microbatches))

    def positions(self) -> List[Stage]:
        """Pipeline positions (pp*vpp stages) with composed fwd/bwd
        events — precompute once, pass to predict()/replay() and the
        search pruner so candidates don't rebuild the model graph."""
        return build_positions(self.cfg, self.strategy, self.microbatch(),
                               self.seq, self.provider.cluster)

    def _result(self, tl: Timeline) -> SimResult:
        bt = tl.batch_time
        return SimResult(
            timeline=tl,
            batch_time=bt,
            throughput_iters=1.0 / bt if bt else 0.0,
            throughput_tokens=self.global_batch * self.seq / bt if bt else 0,
            utilization=tl.utilization(),
            bubble_fraction=tl.bubble_fraction(),
        )

    # ---- Table 3 accounting ----
    def profiling_report(self) -> Dict[str, float]:
        micro = self.global_batch // (self.strategy.dp
                                      * self.strategy.microbatches)
        stages = build_stage_events(self.cfg, self.strategy, micro, self.seq,
                                    self.provider.cluster.devices_per_island)
        counts = unique_events(stages, self.strategy,
                               self.provider.cluster.devices_per_island)
        profile = profile_events(counts.keys(), self.provider)
        return profiling_cost(counts, profile)
