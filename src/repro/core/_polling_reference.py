"""Historical polling scheduler — benchmark baseline + differential oracle.

This is the seed implementation of ``construct_timeline`` (pre
``repro.core.engine``), kept verbatim for two purposes only:

* ``benchmarks/bench_timeline.py`` measures the event-flow engine's
  speedup against it;
* ``tests/test_engine.py`` asserts the engine's predict path (zero
  noise) is bit-identical to it.

It rescans every (replica, device) queue until progress —
O((dp·pp)²·tasks) — and carries two replay-oracle modeling bugs the
engine fixes (per-activity clock offsets; non-synchronizing DP
all-reduce). Do NOT use it for new code; ``construct_timeline`` in
``repro.core.hierarchy`` is the supported entry point.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.configs.base import ArchConfig
from repro.core.events import ComposedEvent, Event, Stage, Strategy
from repro.core.profiler import Provider
from repro.core.schedules import build_schedule
from repro.core.timeline import Activity, Timeline


@dataclasses.dataclass
class _Jitter:
    rng: Optional[np.random.RandomState]
    sigma: float
    speed: np.ndarray            # (dp, pp) per-device multiplicative factor

    def draw(self, mean: float, r: int, d: int) -> float:
        if self.rng is None or mean == 0.0:
            return mean * self.speed[r, d]
        f = max(0.05, 1.0 + self.sigma * self.rng.randn())
        return mean * f * self.speed[r, d]


def construct_timeline_polling(cfg: ArchConfig, strat: Strategy,
                               global_batch: int, seq: int,
                               provider: Provider,
                               jitter_sigma: float = 0.0,
                               straggler_sigma: float = 0.0,
                               clock_sigma: float = 0.0,
                               seed: Optional[int] = None,
                               positions: Optional[List[Stage]] = None
                               ) -> Timeline:
    from repro.core.hierarchy import build_positions

    cluster = provider.cluster
    m = strat.microbatches
    microbatch = max(1, global_batch // (strat.dp * m))
    stages = (positions if positions is not None
              else build_positions(cfg, strat, microbatch, seq, cluster))
    sched = build_schedule(strat.schedule, strat.pp, m, strat.vpp)
    pp, dp, vpp = strat.pp, strat.dp, strat.vpp
    n_pos = len(stages)

    rng = np.random.RandomState(seed) if seed is not None else None
    speed = np.ones((dp, pp))
    if rng is not None and straggler_sigma > 0:
        speed = 1.0 + straggler_sigma * np.abs(rng.randn(dp, pp))
    jit = _Jitter(rng, jitter_sigma, speed)

    def composed_dur(ce: ComposedEvent, r: int, d: int) -> float:
        return sum(jit.draw(provider.time(e), r, d) for e in ce.events)

    def p2p_event(pos: int, phase: str) -> Event:
        span = strat.mp + 1
        scope = ("intra" if span <= cluster.devices_per_island else "inter")
        return Event(kind="p2p", name=f"p2p:{phase}:pos{pos}",
                     nbytes=stages[pos].boundary_act_bytes, scope=scope)

    acts: List[Activity] = []       # per (r, d) canonical activities
    free: Dict[Tuple[int, int], float] = {(r, d): 0.0
                                          for r in range(dp)
                                          for d in range(pp)}
    ptr = {(r, d): 0 for r in range(dp) for d in range(pp)}
    f_end: Dict[Tuple[int, int, int], float] = {}   # (r, pos, micro)
    arr_f: Dict[Tuple[int, int, int], float] = {}   # forward act arrival
    arr_b: Dict[Tuple[int, int, int], float] = {}   # backward grad arrival

    total = dp * sum(len(s) for s in sched)
    done = 0
    while done < total:
        progress = False
        for r in range(dp):
            for d in range(pp):
                while ptr[(r, d)] < len(sched[d]):
                    t = sched[d][ptr[(r, d)]]
                    pos = t.chunk * pp + d
                    if t.phase == "F":
                        if pos == 0:
                            ready = 0.0
                        else:
                            key = (r, pos, t.micro)
                            if key not in arr_f:
                                break
                            ready = arr_f[key]
                        dur = composed_dur(stages[pos].fwd, r, d)
                    else:
                        fkey = (r, pos, t.micro)
                        if fkey not in f_end:
                            break
                        ready = f_end[fkey]
                        if pos < n_pos - 1:
                            bkey = (r, pos, t.micro)
                            if bkey not in arr_b:
                                break
                            ready = max(ready, arr_b[bkey])
                        dur = composed_dur(stages[pos].bwd, r, d)

                    start = max(free[(r, d)], ready)
                    end = start + dur
                    free[(r, d)] = end
                    acts.append(Activity(
                        device=r * pp + d,
                        name=f"{t.phase}:s{pos}:m{t.micro}",
                        kind=t.phase, start=start, end=end,
                        stage=pos, micro=t.micro))

                    if t.phase == "F":
                        f_end[(r, pos, t.micro)] = end
                        if pos < n_pos - 1:
                            pt = jit.draw(provider.time(p2p_event(pos, "f")),
                                          r, d)
                            arr_f[(r, pos + 1, t.micro)] = end + pt
                            acts.append(Activity(
                                device=r * pp + d,
                                name=f"P2P:f:s{pos}:m{t.micro}",
                                kind="P2P", start=end, end=end + pt,
                                stage=pos, micro=t.micro))
                    else:
                        if pos > 0:
                            pt = jit.draw(
                                provider.time(p2p_event(pos - 1, "b")), r, d)
                            arr_b[(r, pos - 1, t.micro)] = end + pt
                            acts.append(Activity(
                                device=r * pp + d,
                                name=f"P2P:b:s{pos}:m{t.micro}",
                                kind="P2P", start=end, end=end + pt,
                                stage=pos, micro=t.micro))
                    ptr[(r, d)] += 1
                    done += 1
                    progress = True
        if not progress:
            raise RuntimeError(
                f"pipeline schedule deadlock: {strat.label()} "
                f"{strat.schedule} done={done}/{total}")

    # ---------------- DP level: gradient sync + optimizer ----------------
    chip = cluster.chip
    for d in range(pp):
        pos_list = [c * pp + d for c in range(vpp) if c * pp + d < n_pos]
        pbytes = sum(stages[p].param_bytes for p in pos_list) / max(1, strat.mp)
        pbytes *= strat.grad_compress       # int8 compression what-if
        # asynchronous pipelining (PipeDream): no global weight sync —
        # each device steps its optimizer immediately (paper §7)
        sync = dp > 1 and strat.schedule != "pipedream"
        sync_start = max(free[(r, d)] for r in range(dp))
        for r in range(dp):
            t0 = max(free[(r, d)], sync_start if sync else free[(r, d)])
            if sync:
                span = dp * pp * strat.mp
                scope = ("intra" if span <= cluster.devices_per_island
                         else "inter")
                if strat.zero1:
                    ar = (provider.time(Event(
                        kind="collective", name=f"dp_rs:d{d}",
                        coll_op="reduce_scatter", nbytes=pbytes,
                        n_dev=dp, scope=scope))
                        + provider.time(Event(
                            kind="collective", name=f"dp_ag:d{d}",
                            coll_op="all_gather", nbytes=pbytes,
                            n_dev=dp, scope=scope)))
                else:
                    ar = provider.time(Event(
                        kind="collective", name=f"dp_ar:d{d}",
                        coll_op="all_reduce", nbytes=pbytes,
                        n_dev=dp, scope=scope))
                # SEED BUG (fixed in repro.core.engine): each replica
                # exits the blocking collective at its own jittered time.
                ar = jit.draw(ar, r, d)
                acts.append(Activity(device=r * pp + d, name=f"AR:d{d}",
                                     kind="AR", start=t0, end=t0 + ar,
                                     stage=d))
                t0 += ar
            # AdamW: streams fp32 master params + m + v (~6 passes of 2x)
            opt_bytes = pbytes * (1 if not strat.zero1 else 1.0 / dp)
            opt = jit.draw(6.0 * opt_bytes * 2 / chip.hbm_bw, r, d)
            acts.append(Activity(device=r * pp + d, name=f"OPT:d{d}",
                                 kind="OPT", start=t0, end=t0 + opt,
                                 stage=d))
            free[(r, d)] = t0 + opt

    # ---------------- replicate over MP ranks ----------------
    out: List[Activity] = []
    mp = strat.mp
    for a in acts:
        base = a.device * mp
        for j in range(mp):
            off = 0.0
            # SEED BUG (fixed in repro.core.engine): clock skew drawn
            # per ACTIVITY instead of once per device per run.
            if rng is not None and clock_sigma > 0:
                off = clock_sigma * rng.randn()
            out.append(dataclasses.replace(
                a, device=base + j, start=a.start + off, end=a.end + off))
    return Timeline(out, n_devices=dp * pp * mp)
