"""Use-case: automatic hybrid-parallel strategy search (paper §6).

DEPRECATED compatibility surface over :mod:`repro.search` — the
subsystem that adds a shared profile cache, dominance pruning,
mega-batch vectorized scoring and multi-cluster Pareto search.
``grid_search`` keeps the seed signature and behavior (every candidate
fully simulated, one provider, full sorted ranking with OOM entries
included) but emits a :class:`DeprecationWarning`: new code should
drive :class:`repro.search.SearchEngine` directly.
"""
from __future__ import annotations

import warnings
from typing import List, Optional, Sequence

from repro.configs.base import ArchConfig
from repro.core.costmodel import V5E_POD
from repro.core.profiler import AnalyticalProvider, Provider
from repro.search.cache import ProfileCache
from repro.search.engine import SearchEngine, SearchEntry
from repro.search.prune import estimate_memory, memory_feasible

__all__ = ["SearchEntry", "grid_search", "memory_feasible",
           "estimate_memory"]


def grid_search(cfg: ArchConfig, n_devices: int, global_batch: int,
                seq: int, provider: Optional[Provider] = None,
                microbatches: Optional[Sequence[int]] = None,
                schedules: Sequence[str] = ("1f1b",),
                check_memory: bool = False) -> List[SearchEntry]:
    """Deprecated: use ``repro.search.SearchEngine(...).search(...)``."""
    warnings.warn(
        "repro.core.search.grid_search is deprecated; use "
        "repro.search.SearchEngine(cfg, ...).search(...)",
        DeprecationWarning, stacklevel=2)
    provider = provider or AnalyticalProvider(V5E_POD)
    engine = SearchEngine(cfg, cache=ProfileCache.from_provider(provider),
                          prune=False, check_memory=check_memory)
    result = engine.search(n_devices, global_batch, seq,
                           microbatches=microbatches, schedules=schedules)
    return result.entries
