"""Pallas TPU RMSNorm kernel.

Row-blocked: each grid step normalizes (block_rows, d) in VMEM — one
HBM read + one write per element (XLA's unfused path reads x twice:
once for the variance reduction, once for the scale). d is the lane
dimension; block_rows x d tiles are VPU-shaped (8,128)-aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, scale_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps)
                  * scale_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6,
            block_rows: int = 128, interpret: bool = True) -> jax.Array:
    """x: (..., d); scale: (d,)."""
    orig_shape = x.shape
    d = x.shape[-1]
    x2 = x.reshape(-1, d)
    n = x2.shape[0]
    block_rows = min(block_rows, n)
    pad = (-n) % block_rows
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    grid = (x2.shape[0] // block_rows,)

    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        interpret=interpret,
    )(x2, scale)
    return out[:n].reshape(orig_shape)
