"""Strategy-search throughput benchmark (candidates/sec).

Compares three engine configurations on the same grid:

* ``naive``  — per-candidate profiling, no pruning (the seed
  ``grid_search`` behavior);
* ``cached`` — shared profile cache, no pruning;
* ``pruned`` — shared cache + memory filter + work-lower-bound pruning
  (the production path).

Prints ``name,us_per_call,derived`` CSV like ``benchmarks/run.py``.

    PYTHONPATH=src python benchmarks/bench_search.py [--smoke]
"""
from __future__ import annotations

import argparse
import sys

from repro.configs.base import get_config, smoke_config
from repro.core import get_cluster
from repro.search import SearchEngine, format_report, search_report


def run_mode(name, cfg, clusters, devices, gb, seq, grid, share_cache,
             prune):
    eng = SearchEngine(cfg, clusters=clusters, share_cache=share_cache,
                       prune=prune, check_memory=True)
    res = eng.search(devices, gb, seq, **grid)
    st = res.stats
    best = res.best()
    row = (f"search/{name}", st.wall_time_s * 1e6,
           f"cand/s={st.candidates_per_s:.1f} "
           f"evals={st.provider_evaluations} "
           f"simulated={st.evaluated} pruned={st.pruned_bound} "
           f"oom={st.pruned_memory} "
           f"best={best.strategy.label() if best else 'n/a'}")
    return res, row


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config + small grid (CI job)")
    ap.add_argument("--arch", default="bert_exlarge")
    ap.add_argument("--devices", type=int, default=64)
    ap.add_argument("--global-batch", type=int, default=64)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--clusters", default="a40-cluster",
                    help="comma-separated ClusterSpec names")
    ap.add_argument("--report", action="store_true",
                    help="print the full search report for 'pruned'")
    args = ap.parse_args()

    if args.smoke:
        cfg = smoke_config(get_config("gpt2_345m"))
        devices, gb, seq = 16, 16, 128
        grid = dict(microbatches=(1, 2, 4, 8),
                    schedules=("1f1b", "gpipe"))
    else:
        cfg = get_config(args.arch)
        devices, gb, seq = args.devices, args.global_batch, args.seq
        grid = dict(schedules=("1f1b", "gpipe", "interleaved"))
    clusters = [get_cluster(n) for n in args.clusters.split(",")]

    print("name,us_per_call,derived")
    rows = []
    naive_res, row = run_mode("naive", cfg, clusters, devices, gb, seq,
                              grid, share_cache=False, prune=False)
    rows.append(row)
    cached_res, row = run_mode("cached", cfg, clusters, devices, gb, seq,
                               grid, share_cache=True, prune=False)
    rows.append(row)
    pruned_res, row = run_mode("pruned", cfg, clusters, devices, gb, seq,
                               grid, share_cache=True, prune=True)
    rows.append(row)

    ne = naive_res.stats.provider_evaluations
    ce = cached_res.stats.provider_evaluations
    rows.append(("search/eval_reduction", 0.0,
                 f"naive/cached={ne / ce if ce else 0.0:.2f}x"))
    speed = (pruned_res.stats.candidates_per_s
             / naive_res.stats.candidates_per_s
             if naive_res.stats.candidates_per_s else 0.0)
    rows.append(("search/speedup", 0.0,
                 f"pruned_vs_naive={speed:.2f}x"))
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")

    ok = (naive_res.best() and pruned_res.best()
          and naive_res.best().strategy == pruned_res.best().strategy)
    if not ok:
        print("search/ERROR,0,best strategy mismatch", file=sys.stderr)
        sys.exit(1)
    if args.report:
        print(file=sys.stderr)
        print(format_report(search_report(pruned_res)), file=sys.stderr)


if __name__ == "__main__":
    main()
