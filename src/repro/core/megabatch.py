"""Mega-batch predict: score many candidate engines in one array pass.

The paper's §6 use-case is bounded by how many strategies the model can
score per second. Per-candidate ``engine.run()`` pays a Python
scheduling loop per candidate; this module compiles the zero-noise
predict recurrence of K heterogeneous :class:`EventFlowEngine`s into
ONE padded ``(steps, K)`` array program and evaluates all candidates
simultaneously.

The key identity: along a candidate's :meth:`EventFlowEngine.topo_order`
every task's start time is

    start = max over deps of (end[dep] + delay)

with at most THREE dependencies — the previous task on the same device
(delay 0), the forward activation arrival (F producer at ``pos-1`` plus
``p2p_base[pos-1]``), and for B tasks the backward arrival (B producer
at ``pos+1`` plus ``p2p_base[pos]``). Step ``j`` of the program
evaluates the j-th topo task of EVERY candidate at once: each
candidate's topo order guarantees its deps landed at earlier steps, so
the per-step dependency pattern is a gather + add + row-max over a
``(K, 3)`` block. Candidates shorter than the longest one write their
padding steps into a per-program trash slot and read the constant
dummy slot (end = 0.0).

Bit-identity (the repo's standing bar for caching/parallelism work):
the NumPy backend performs exactly the FP operations of the per-engine
predict path — ``max`` is exact regardless of grouping, every addition
pairs the same operands (`end + p2p_base`, `start + dur`,
``free + ar_base + opt_base``), and the dummy slot's ``0.0 + 0.0``
contributions are absorbed exactly by the surrounding max over times
that are ≥ 0. Batch times are therefore bit-identical per candidate to
``engine.run().batch_time`` (asserted by the differential oracle in
``tests/test_search_engine.py``). Busy/bubble aggregates use array
segment sums whose FP summation order differs from the sequential
loop — they match to rounding, not to the bit, and are not gated.

Backends: ``numpy`` (default — the bit-identical reference),
``jax`` (``lax.scan`` over steps) and ``pallas`` (fused per-step
max/accumulate kernel) for accelerators; see
:mod:`repro.kernels.megabatch_scan`. ``auto`` picks numpy unless jax
reports a GPU/TPU. jax is imported lazily — environments without it
(the numpy-only CI jobs) never touch the accelerator backends.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.engine import EventFlowEngine

#: global slot 0 — constant end time 0.0, the identity dependency.
DUMMY_SLOT = 0

BACKENDS = ("auto", "numpy", "jax", "pallas")


@dataclasses.dataclass
class MegaPredict:
    """Per-candidate zero-noise predictions, one row per engine."""
    batch_times: np.ndarray        # (K,) — bit-identical to engine.run()
    bubble_fractions: np.ndarray   # (K,) — matches to rounding, not bits
    backend: str                   # backend that evaluated the recurrence
    n_candidates: int
    n_steps: int                   # padded program length (max task count)
    n_slots: int                   # global end-time vector length


def _flat(lists: Sequence[Sequence], dtype) -> np.ndarray:
    """Concatenate per-device task metadata lists into one flat array."""
    return np.concatenate(
        [np.asarray(lst, dtype=dtype) for lst in lists]) if lists else \
        np.zeros(0, dtype=dtype)


class MegaBatch:
    """Compiled array program over K candidate engines.

    Compile once (pure function of the engines' builds + schedules),
    then :meth:`predict` any number of times. Engines may be fully
    heterogeneous — different pp/microbatches/schedule/vpp — the
    program pads every candidate to the longest task count.
    """

    def __init__(self, engines: Sequence[EventFlowEngine], perturb=None,
                 verify=None):
        engines = list(engines)
        self.engines = engines
        # a Perturbation's straggler multipliers scale the profiled
        # means at compile time (same operand pairings as the engine's
        # speed plane, so candidate rows stay bit-identical to
        # engine.run(perturb=...)); perturb=None compiles byte-identical
        # arrays to the historical program. The single-replica program
        # requires effects uniform across DP (pipe_scale raises
        # otherwise); faults are run-level splices and rejected here.
        if perturb is not None and getattr(perturb, "faults", ()):
            raise ValueError(
                "mega-batch predict evaluates one step; fault recovery "
                "is spliced at the run level — use "
                "DistSim.simulate(perturb=...)")
        self.perturb = perturb
        K = len(engines)
        self.K = K
        sizes = [e.total_tasks for e in engines]
        self.T = max(sizes) if K else 0
        total = int(sum(sizes))
        self.total = total
        # slot 0: dummy (end 0.0); slot total+1: trash for padding steps
        self.n_slots = total + 2
        trash = total + 1

        T, K = self.T, self.K
        self._out = np.full((T, K), trash, dtype=np.int64)
        # dep planes kept separate: the numpy hot loop runs ~T small
        # array steps, and three flat (K,) gathers beat one (K, 3)
        # gather + axis reduction. dep0 (device serialization) always
        # has delay 0, so it skips the add entirely — max() absorbs the
        # dropped `+ 0.0` exactly.
        self._dep0 = np.zeros((T, K), dtype=np.int64)
        self._dep1 = np.zeros((T, K), dtype=np.int64)
        self._dep2 = np.zeros((T, K), dtype=np.int64)
        self._del1 = np.zeros((T, K))
        self._del2 = np.zeros((T, K))
        self._dur = np.zeros((T, K))

        self._pp = np.asarray([e.strat.pp for e in engines], dtype=np.int64) \
            if K else np.zeros(0, dtype=np.int64)
        ppmax = int(self._pp.max()) if K else 0
        self.ppmax = ppmax
        # per-(candidate, pipeline-device) epilogue inputs, zero-padded
        self._free_slot = np.zeros((K, ppmax), dtype=np.int64)
        self._ar = np.zeros((K, ppmax))
        self._opt = np.zeros((K, ppmax))
        # per-task epilogue inputs, flat over all candidates' tasks
        self._seg = np.zeros(total, dtype=np.int64)   # k * ppmax + device
        self._send = np.full(total, -np.inf)          # boundary-send delay

        base = 1
        for k, eng in enumerate(engines):
            base = self._compile_one(k, eng, base, trash)

        # construction-time static verification of the compiled array
        # program (repro.analyze): verify=None defers to REPRO_VERIFY —
        # on in tests/CI, off on the search hot path.
        from repro.analyze.findings import default_verify
        if default_verify(verify):
            from repro.analyze.findings import raise_on_findings
            from repro.analyze.graph import verify_megabatch
            raise_on_findings(verify_megabatch(self))

    # ------------------------------------------------------------------

    def _compile_one(self, k: int, eng: EventFlowEngine, base: int,
                     trash: int) -> int:
        """Lower one engine's task recurrence into rows of the program.

        Slots ``base .. base+n`` hold this candidate's task end times in
        device-major schedule order; returns the next free slot."""
        pp, n_pos, m = eng.strat.pp, eng.n_pos, eng.m
        # deterministic straggler multiplier per pipeline device (None
        # when unperturbed — every array below then compiles
        # byte-identical to the historical program)
        scale = (self.perturb.pipe_scale(eng.strat)
                 if self.perturb is not None else None)
        n = eng.total_tasks
        n_per_dev = np.asarray([len(t) for t in eng.task_isf],
                               dtype=np.int64)
        dev_off = np.concatenate([[0], np.cumsum(n_per_dev)])
        if n == 0:
            return base

        isf = _flat(eng.task_isf, bool)
        pos = _flat(eng.task_pos, np.int64)
        mic = _flat(eng.task_micro, np.int64)
        dev = np.repeat(np.arange(pp, dtype=np.int64), n_per_dev)
        slots = base + np.arange(n, dtype=np.int64)

        fwd = np.asarray(eng.fwd_base)
        bwd = np.asarray(eng.bwd_base)
        p2p = np.asarray(eng.p2p_base)

        # producer lookup: global slot of the F / B task at (pos, micro)
        f_slot = np.zeros((n_pos, m), dtype=np.int64)
        b_slot = np.zeros((n_pos, m), dtype=np.int64)
        f_slot[pos[isf], mic[isf]] = slots[isf]
        b_slot[pos[~isf], mic[~isf]] = slots[~isf]

        # dep 0: previous task on the same device (device serialization)
        dep0 = slots - 1
        first = dev_off[:-1][n_per_dev > 0]
        dep0[first] = DUMMY_SLOT

        # dep 1: F tasks wait on the forward arrival from pos-1; B tasks
        # wait on their own position's F output (delay 0)
        dep1 = np.full(n, DUMMY_SLOT, dtype=np.int64)
        del1 = np.zeros(n)
        f_recv = isf & (pos > 0)
        dep1[f_recv] = f_slot[pos[f_recv] - 1, mic[f_recv]]
        del1[f_recv] = p2p[pos[f_recv] - 1]
        dep1[~isf] = f_slot[pos[~isf], mic[~isf]]

        # dep 2: B tasks below the last position also wait on the
        # backward arrival from pos+1
        dep2 = np.full(n, DUMMY_SLOT, dtype=np.int64)
        del2 = np.zeros(n)
        b_recv = (~isf) & (pos < n_pos - 1)
        dep2[b_recv] = b_slot[pos[b_recv] + 1, mic[b_recv]]
        del2[b_recv] = p2p[pos[b_recv]]

        dur = np.where(isf, fwd[pos], bwd[pos])

        # boundary sends: the send arrival extends the SENDING device's
        # pipeline-last time (run()'s p2p_ends bookkeeping)
        send = np.full(n, -np.inf)
        f_send = isf & (pos < n_pos - 1)
        send[f_send] = p2p[pos[f_send]]
        b_send = (~isf) & (pos > 0)
        send[b_send] = p2p[pos[b_send] - 1]

        if scale is not None:
            # every duration/delay is scaled by its EXECUTING device —
            # p2p by the sender (forward boundary p sends from device
            # p % pp, backward boundary p from (p+1) % pp) — the exact
            # products engine._sample forms via its speed plane
            dur = dur * scale[dev]
            del1[f_recv] = del1[f_recv] * scale[(pos[f_recv] - 1) % pp]
            del2[b_recv] = del2[b_recv] * scale[(pos[b_recv] + 1) % pp]
            send[f_send] = send[f_send] * scale[dev[f_send]]
            send[b_send] = send[b_send] * scale[dev[b_send]]

        if getattr(eng, "_decode", False):
            # decode: step t's stage 0 waits on step t-1's token
            # feedback from the last stage (dep1) and its arrival floor
            # (dep2 rides the dummy slot: 0.0 + arrival == arrival,
            # absorbed exactly by the row max — engine bit-identity).
            # The feedback p2p is sent by the LAST stage's device, so
            # it takes that device's straggler scale; arrival floors
            # are wall-clock and never scale.
            fb_base = eng.fb_base
            if scale is not None:
                fb_base = fb_base * scale[(n_pos - 1) % pp]
            f0 = isf & (pos == 0)
            later = f0 & (mic > 0)
            dep1[later] = f_slot[n_pos - 1, mic[later] - 1]
            del1[later] = fb_base
            arrival = np.asarray(eng.arrival)
            del2[f0] = arrival[mic[f0]]
            fb_send = isf & (pos == n_pos - 1)
            send[fb_send] = fb_base

        # reorder rows along this candidate's topo order: step j of the
        # program evaluates its j-th ready task
        topo = np.asarray(eng.topo_order(), dtype=np.int64)    # (n, 2)
        perm = dev_off[topo[:, 0]] + topo[:, 1]
        self._out[:n, k] = slots[perm]
        self._dep0[:n, k] = dep0[perm]
        self._dep1[:n, k] = dep1[perm]
        self._dep2[:n, k] = dep2[perm]
        self._del1[:n, k] = del1[perm]
        self._del2[:n, k] = del2[perm]
        self._dur[:n, k] = dur[perm]

        # epilogue: device free slots (last task per device, in schedule
        # order), segment ids, send delays, DP-sync + optimizer means
        last_local = dev_off[1:] - 1
        free = np.where(n_per_dev > 0, slots[last_local], DUMMY_SLOT)
        self._free_slot[k, :pp] = free
        self._seg[base - 1: base - 1 + n] = k * self.ppmax + dev
        self._send[base - 1: base - 1 + n] = send
        if scale is None:
            self._ar[k, :pp] = eng.ar_base   # zeros when engine no-sync
            self._opt[k, :pp] = eng.opt_base
        else:
            self._ar[k, :pp] = np.asarray(eng.ar_base) * scale
            self._opt[k, :pp] = np.asarray(eng.opt_base) * scale
        return base + n

    # ------------------------------------------------------------------
    # evaluation
    # ------------------------------------------------------------------

    def resolve_backend(self, backend: str = "auto") -> str:
        if backend not in BACKENDS:
            raise ValueError(
                f"unknown megabatch backend {backend!r}; "
                f"choose from {BACKENDS}")
        if backend != "auto":
            return backend
        # probe for an accelerator ONLY if the process already paid the
        # jax import — importing jax (~0.5 s) just to answer "auto" on
        # a CPU box would dwarf the search being accelerated. Explicit
        # backend="jax" still imports on demand.
        import sys
        if "jax" in sys.modules:
            try:
                from repro.kernels import megabatch_scan
                if megabatch_scan.accelerator_backend():
                    return "jax"
            except ImportError:  # pragma: no cover - partial install
                pass
        return "numpy"

    def _eval_numpy(self) -> Tuple[np.ndarray, np.ndarray]:
        """Reference evaluation: T steps, each three (K,) gathers, two
        adds and a 3-way max. Exactly the per-engine predict FP
        operations (dep0's delay is 0 by construction and skipped —
        ``max(x, ...)`` vs ``max(x + 0.0, ...)`` is the same bit)."""
        ends = np.zeros(self.n_slots)
        starts = np.zeros(self.n_slots)
        out = self._out
        d0, d1, d2 = self._dep0, self._dep1, self._dep2
        l1, l2, dur = self._del1, self._del2, self._dur
        mx = np.maximum
        for j in range(self.T):
            s = mx(mx(ends[d0[j]], ends[d1[j]] + l1[j]),
                   ends[d2[j]] + l2[j])
            o = out[j]
            starts[o] = s
            ends[o] = s + dur[j]
        return ends, starts

    def _stacked(self) -> Tuple[np.ndarray, np.ndarray]:
        """(T, K, 3) dep/delay stacks — the accelerator-backend layout."""
        dep = np.stack([self._dep0, self._dep1, self._dep2], axis=-1)
        delay = np.stack([np.zeros_like(self._del1), self._del1,
                          self._del2], axis=-1)
        return dep, delay

    def _eval(self, backend: str) -> Tuple[np.ndarray, np.ndarray, str]:
        backend = self.resolve_backend(backend)
        if backend == "numpy" or self.K == 0:
            ends, starts = self._eval_numpy()
            return ends, starts, "numpy"
        from repro.kernels import megabatch_scan
        dep, delay = self._stacked()
        ends, starts = megabatch_scan.scan_steps(
            self._out, dep, delay, self._dur, self.n_slots,
            backend=backend)
        return ends, starts, backend

    def predict_times(self, backend: str = "auto") -> np.ndarray:
        """(K,) predicted batch times — ``engine.run().batch_time`` per
        candidate, bit-identical on the numpy backend."""
        return self.predict(backend).batch_times

    def predict(self, backend: str = "auto") -> MegaPredict:
        if self.K == 0:
            return MegaPredict(np.zeros(0), np.zeros(0), "numpy", 0,
                               self.T, self.n_slots)
        ends, starts, used = self._eval(backend)
        K, ppmax, total = self.K, self.ppmax, self.total
        task_end = ends[1: total + 1]
        task_start = starts[1: total + 1]

        # pipeline-last per (candidate, device): task ends and boundary
        # send arrivals, segment-maxed (run()'s pipe_last fold)
        last_pipe = np.zeros(K * ppmax)
        np.maximum.at(last_pipe, self._seg, task_end)
        np.maximum.at(last_pipe, self._seg, task_end + self._send)
        last_pipe = last_pipe.reshape(K, ppmax)

        # DP sync + optimizer epilogue. Non-sync engines carry ar == 0,
        # so `free + 0.0` reproduces their `t0 = free` path exactly.
        free = ends[self._free_slot]
        opt_t1 = (free + self._ar) + self._opt
        last = np.maximum(last_pipe, opt_t1)
        batch_times = np.maximum(last.max(axis=1), 0.0)

        # busy / bubble (not bit-gated: segment-sum order differs from
        # the sequential accumulation)
        busy = np.zeros(K * ppmax)
        np.add.at(busy, self._seg, task_end - task_start)
        busy = busy.reshape(K, ppmax) + self._ar + self._opt
        with np.errstate(invalid="ignore", divide="ignore"):
            util = np.where(batch_times[:, None] > 0,
                            busy / batch_times[:, None], 0.0)
        mean_util = util.sum(axis=1) / self._pp
        bubble = 1.0 - mean_util
        return MegaPredict(batch_times, bubble, used, K, self.T,
                           self.n_slots)


def megabatch_predict(engines: Sequence[EventFlowEngine],
                      backend: str = "auto", perturb=None) -> MegaPredict:
    """One-shot convenience: compile + evaluate K engines, optionally
    under a :class:`repro.core.perturb.Perturbation` straggler plane
    (uniform across DP; each candidate row stays bit-identical to
    ``engine.run(perturb=perturb)``)."""
    return MegaBatch(engines, perturb=perturb).predict(backend)
