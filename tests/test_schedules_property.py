"""Property-based tests (hypothesis) for pipeline schedules and the
timeline constructor's invariants."""
import pytest

hp = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")

from repro.configs.base import get_config
from repro.core import A40_CLUSTER, AnalyticalProvider, DistSim, Strategy
from repro.core.schedules import build_schedule

CFG = get_config("gpt2_345m")
PROVIDER = AnalyticalProvider(A40_CLUSTER)


@hp.given(pp=st.integers(1, 8), m=st.integers(1, 16),
          name=st.sampled_from(["gpipe", "1f1b", "pipedream"]))
@hp.settings(max_examples=40, deadline=None)
def test_schedule_task_counts(pp, m, name):
    sched = build_schedule(name, pp, m)
    assert len(sched) == pp
    for tasks in sched:
        fs = [t for t in tasks if t.phase == "F"]
        bs = [t for t in tasks if t.phase == "B"]
        assert len(fs) == m and len(bs) == m
        assert sorted(t.micro for t in fs) == list(range(m))
        assert sorted(t.micro for t in bs) == list(range(m))


@hp.given(pp=st.integers(1, 6), m=st.integers(1, 12), vpp=st.integers(1, 3))
@hp.settings(max_examples=30, deadline=None)
def test_interleaved_task_counts(pp, m, vpp):
    sched = build_schedule("interleaved", pp, m, vpp)
    for tasks in sched:
        fs = [t for t in tasks if t.phase == "F"]
        assert len(fs) == m * vpp
        assert len(tasks) == 2 * m * vpp


@hp.given(pp=st.integers(1, 8), m=st.integers(1, 16))
@hp.settings(max_examples=30, deadline=None)
def test_backward_after_forward_same_stage(pp, m):
    """On every device, B(micro) appears after F(micro)."""
    for name in ("gpipe", "1f1b", "pipedream"):
        for tasks in build_schedule(name, pp, m):
            seen_f = set()
            for t in tasks:
                if t.phase == "F":
                    seen_f.add(t.micro)
                else:
                    assert t.micro in seen_f


@hp.given(pp=st.sampled_from([1, 2, 4]), dp=st.sampled_from([1, 2]),
          mp=st.sampled_from([1, 2]),
          m=st.sampled_from([1, 2, 4]),
          schedule=st.sampled_from(["gpipe", "1f1b", "pipedream"]))
@hp.settings(max_examples=20, deadline=None)
def test_timeline_constructs_without_deadlock(pp, dp, mp, m, schedule):
    """Any feasible strategy builds a valid timeline: no deadlock, no
    overlapping compute on one device, batch time ≥ critical stage."""
    gb = dp * m                         # microbatch size 1
    sim = DistSim(CFG, Strategy(mp=mp, pp=pp, dp=dp, microbatches=m,
                                schedule=schedule), gb, 128, PROVIDER)
    res = sim.simulate().result()
    tl = res.timeline
    assert tl.batch_time > 0
    for dev, acts in tl.by_device().items():
        compute = [a for a in acts if a.kind in ("F", "B", "AR", "OPT")]
        for a, b in zip(compute, compute[1:]):
            assert b.start >= a.end - 1e-9, (dev, a, b)


@hp.given(pp=st.integers(1, 6), m=st.integers(1, 12), vpp=st.integers(1, 3),
          name=st.sampled_from(["gpipe", "1f1b", "interleaved",
                                "pipedream"]))
@hp.settings(max_examples=40, deadline=None)
def test_task_instances_unique_per_stage(pp, m, vpp, name):
    """Invariant: every (phase, micro, chunk) appears exactly once per
    stage — duplicated or dropped tasks would silently skew both the
    model and the replay oracle."""
    for tasks in build_schedule(name, pp, m, vpp):
        keys = [(t.phase, t.micro, t.chunk) for t in tasks]
        assert len(keys) == len(set(keys))


@hp.given(pp=st.integers(1, 8), m=st.integers(1, 16))
@hp.settings(max_examples=40, deadline=None)
def test_1f1b_in_flight_bounded(pp, m):
    """1F1B's point: at most min(pp, m) microbatches in flight per
    stage (GPipe holds all m) — bounds activation memory."""
    for tasks in build_schedule("1f1b", pp, m):
        in_flight = peak = 0
        for t in tasks:
            in_flight += 1 if t.phase == "F" else -1
            peak = max(peak, in_flight)
        assert peak <= min(pp, m)
        assert in_flight == 0              # drained at the flush


@hp.given(pp=st.integers(1, 6), m=st.integers(1, 12), vpp=st.integers(1, 4))
@hp.settings(max_examples=40, deadline=None)
def test_interleaved_covers_all_chunks(pp, m, vpp):
    """Every device runs all vpp virtual chunks, each (micro, chunk)
    exactly once per phase."""
    for tasks in build_schedule("interleaved", pp, m, vpp):
        for phase in ("F", "B"):
            pairs = [(t.micro, t.chunk) for t in tasks if t.phase == phase]
            assert sorted(pairs) == sorted(
                (i, c) for i in range(m) for c in range(vpp))
            assert {t.chunk for t in tasks if t.phase == phase} \
                == set(range(vpp))


@hp.given(pp=st.integers(1, 8), m=st.integers(1, 16))
@hp.settings(max_examples=40, deadline=None)
def test_pipedream_in_flight_bounded_and_drained(pp, m):
    """PipeDream steady state: device d keeps at most min(m, pp - d)
    microbatches in flight (its deeper warmup), and one modeled epoch
    drains completely."""
    for d, tasks in enumerate(build_schedule("pipedream", pp, m)):
        in_flight = peak = 0
        for t in tasks:
            in_flight += 1 if t.phase == "F" else -1
            peak = max(peak, in_flight)
        assert peak <= min(m, pp - d)
        assert in_flight == 0


@hp.given(m=st.sampled_from([2, 4, 8]), seed=st.integers(0, 5))
@hp.settings(max_examples=12, deadline=None)
def test_replay_jitter_bounded(m, seed):
    """Replay with 2.5% event jitter stays within ~10% of prediction."""
    sim = DistSim(CFG, Strategy(pp=2, dp=2, microbatches=m), 2 * m, 128,
                  PROVIDER)
    pred = sim.simulate().result()
    act = sim.simulate(seeds=seed).result()
    assert abs(pred.batch_time - act.batch_time) / act.batch_time < 0.10
