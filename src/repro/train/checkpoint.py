"""Sharded checkpoint/restore with manifest + atomic commit.

Layout (one directory per step):

    <dir>/step_000042/
        manifest.json      # step, leaf paths/shapes/dtypes, status
        arr_<i>.npy        # one file per leaf (host-local shard on a real
                           # cluster; full array on single-host)

Fault-tolerance properties:
  * atomic: written to ``step_X.tmp`` then renamed — a crash mid-write
    never corrupts the latest complete checkpoint;
  * self-describing: restore validates shapes/dtypes against the target
    pytree and fails loudly on config drift;
  * bounded: ``keep`` newest checkpoints retained;
  * resumable: ``latest_step`` scans the directory, so a restarted job
    (elastic rescheduling, preemption) continues from the last commit.

On a multi-host cluster each host writes only the shards it owns
(``jax.experimental.multihost_utils``); this container is single-host,
where process_index()==0 owns everything — same code path.

jax is imported lazily inside the functions that flatten/device_get
real pytrees: the manifest helpers (:func:`manifest_nbytes`,
:func:`synthetic_manifest`) are pure numpy, so engine-side code
(``repro.core.perturb`` sizing restore-read events) never drags jax
onto CPU-only boxes — the ``megabatch`` auto-backend rule.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Dict, Mapping, Optional, Tuple

import numpy as np


def _leaf_paths(tree: Any):
    import jax
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in leaves]
    return names, [l for _, l in leaves], treedef


def manifest_nbytes(manifest: Mapping) -> float:
    """Total bytes described by a checkpoint manifest — works on
    manifests written by :func:`save` and synthetic ones from
    :func:`synthetic_manifest` (pure numpy; no jax import)."""
    total = 0.0
    for e in manifest["leaves"]:
        n = 1
        for s in e["shape"]:
            n *= int(s)
        total += n * np.dtype(e["dtype"]).itemsize
    return float(total)


def synthetic_manifest(step: int, named_bytes: Mapping[str, float],
                       dtype: str = "float32") -> Dict:
    """A model-level manifest (no arrays on disk): one 1-D leaf per
    ``name -> nbytes`` entry, byte counts rounded to whole elements.
    Shaped exactly like :func:`save`'s ``manifest.json`` so consumers
    (``repro.core.perturb`` restore-read sizing, tooling) use one
    accounting path for real and hypothetical checkpoints."""
    item = np.dtype(dtype).itemsize
    leaves = []
    for i, (name, nbytes) in enumerate(named_bytes.items()):
        leaves.append({"i": i, "path": str(name),
                       "shape": [max(0, int(round(float(nbytes) / item)))],
                       "dtype": str(np.dtype(dtype))})
    return {"step": int(step), "leaves": leaves}


def save(directory: str, step: int, tree: Any, keep: int = 3) -> str:
    """Write checkpoint atomically; returns the final path. ``keep``
    newest checkpoints are retained (``keep=0`` retains nothing)."""
    import jax
    if keep < 0:
        raise ValueError(f"keep must be >= 0, got {keep}")
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    names, leaves, _ = _leaf_paths(tree)
    manifest = {"step": step, "leaves": []}
    for i, (name, leaf) in enumerate(zip(names, leaves)):
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(tmp, f"arr_{i}.npy"), arr)
        manifest["leaves"].append(
            {"i": i, "path": name, "shape": list(arr.shape),
             "dtype": str(arr.dtype)})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                      # atomic commit

    # retention (keep=0 means the [:-0] slice would retain EVERYTHING;
    # spell the "delete all" case out)
    steps = sorted(all_steps(directory))
    for s in (steps[:-keep] if keep else steps):
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"),
                      ignore_errors=True)
    return final


def all_steps(directory: str):
    if not os.path.isdir(directory):
        return []
    out = []
    for d in os.listdir(directory):
        if d.startswith("step_") and not d.endswith(".tmp"):
            try:
                out.append(int(d[5:]))
            except ValueError:
                pass
    return sorted(out)


def latest_step(directory: str) -> Optional[int]:
    steps = all_steps(directory)
    return steps[-1] if steps else None


def restore(directory: str, tree: Any, step: Optional[int] = None
            ) -> Tuple[Any, int]:
    """Restore into the structure of ``tree`` (shape/dtype validated)."""
    import jax
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)

    names, leaves, treedef = _leaf_paths(tree)
    by_path = {e["path"]: e for e in manifest["leaves"]}
    out = []
    for name, leaf in zip(names, leaves):
        e = by_path.get(name)
        if e is None:
            raise KeyError(f"checkpoint missing leaf {name!r}")
        arr = np.load(os.path.join(path, f"arr_{e['i']}.npy"))
        want = tuple(leaf.shape)
        if tuple(arr.shape) != want:
            raise ValueError(
                f"shape mismatch for {name}: ckpt {arr.shape} vs {want}")
        if (hasattr(leaf, "dtype")
                and np.dtype(arr.dtype) != np.dtype(leaf.dtype)):
            # the docstring's "fails loudly on config drift" promise: a
            # silent astype would hide a changed training config (and
            # quietly round fp32 moments to bf16 or vice versa)
            raise ValueError(
                f"dtype mismatch for {name}: ckpt {arr.dtype} vs "
                f"{np.dtype(leaf.dtype)}")
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out), step
