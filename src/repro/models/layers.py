"""Core transformer layers, pure JAX.

All functions are shape-polymorphic and jit/pjit friendly; attention has
three implementations selectable via ``ModelOptions.attn_impl``:

  * ``naive``     — materializes (B,H,S,S) scores. Reference semantics.
  * ``flash_jnp`` — two-level lax.scan blockwise softmax (pure-JAX flash);
                    O(block_q x block_kv) live scores. Default for long S.
  * ``pallas``    — the Pallas TPU kernel in ``repro.kernels`` (train fwd).

Weights use Megatron-style logical axes so ``repro.parallel.sharding`` can
map them onto the mesh: q/k/v projections are column-parallel over heads,
the output projection is row-parallel, the MLP is column→row.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class ModelOptions:
    """Runtime (non-architectural) knobs."""
    dtype: jnp.dtype = jnp.bfloat16
    attn_impl: str = "auto"          # auto | naive | flash_jnp | pallas
    block_q: int = 512
    block_kv: int = 1024
    remat: bool = True               # activation checkpointing per layer
    moe_impl: str = "gather"         # gather | dense_dispatch
    # sequence threshold above which "auto" switches naive → flash_jnp
    flash_threshold: int = 2048
    # Megatron-SP: PartitionSpec constraint applied to the residual stream
    # at layer boundaries (shards the scan carry → activation memory / mp)
    act_spec: object = None
    # attention-internal layout: (batch, seq, heads, hd) — heads over
    # `model` (the Megatron decomposition); forces the SP all-gather to
    # happen exactly once at the qkv projections
    qkv_spec: object = None
    # separate spec for K/V: GQA kv-head count may not divide the model
    # axis (then KV heads are replicated across the TP group)
    kv_spec: object = None
    # explicit expert parallelism (moe_impl="ep_a2a"): experts sharded
    # over `ep_axis`, tokens over `dp_axes` (+ seq over ep_axis)
    ep_axis: object = None
    dp_axes: object = None


def constrain(x: jax.Array, opts: "ModelOptions") -> jax.Array:
    if opts.act_spec is not None:
        return jax.lax.with_sharding_constraint(x, opts.act_spec)
    return x


def constrain_qkv(x: jax.Array, opts: "ModelOptions",
                  is_kv: bool = False) -> jax.Array:
    spec = opts.kv_spec if is_kv else opts.qkv_spec
    if spec is not None:
        return jax.lax.with_sharding_constraint(x, spec)
    return x


DEFAULT_OPTIONS = ModelOptions()


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------

def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * lax.rsqrt(var + eps)) * scale.astype(jnp.float32)).astype(dtype)


# --------------------------------------------------------------------------
# rotary embeddings
# --------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (...,S,hd/2)
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    sin = sin[..., :, None, :]                          # broadcast over heads
    cos = cos[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# attention
# --------------------------------------------------------------------------

def _repeat_kv(k: jax.Array, n_rep: int) -> jax.Array:
    """(B,S,KH,hd) → (B,S,KH*n_rep,hd)."""
    if n_rep == 1:
        return k
    b, s, kh, hd = k.shape
    k = jnp.broadcast_to(k[:, :, :, None, :], (b, s, kh, n_rep, hd))
    return k.reshape(b, s, kh * n_rep, hd)


def _causal_window_mask(q_pos: jax.Array, k_pos: jax.Array,
                        causal: bool, window: Optional[int]) -> jax.Array:
    """Boolean mask (..., Q, K): True = attend."""
    m = jnp.ones(q_pos.shape[:-1] + (q_pos.shape[-1], k_pos.shape[-1]),
                 dtype=bool)
    d = q_pos[..., :, None] - k_pos[..., None, :]
    if causal:
        m &= d >= 0
    if window is not None:
        m &= d < window
    return m


def attention_naive(q, k, v, q_pos, k_pos, causal=True, window=None):
    """q: (B,Sq,H,hd), k/v: (B,Sk,KH,hd). Returns (B,Sq,H,hd)."""
    n_rep = q.shape[2] // k.shape[2]
    k, v = _repeat_kv(k, n_rep), _repeat_kv(v, n_rep)
    scale = q.shape[-1] ** -0.5
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    mask = _causal_window_mask(q_pos, k_pos, causal, window)   # (B,Q,K)
    logits = jnp.where(mask[:, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def _blockify(x, block, pad_value=0.0):
    """(B, S, ...) → (nblocks, B, block, ...)."""
    b, s = x.shape[:2]
    p = (-s) % block
    if p:
        pads = [(0, 0), (0, p)] + [(0, 0)] * (x.ndim - 2)
        x = jnp.pad(x, pads, constant_values=pad_value)
    n = x.shape[1] // block
    x = x.reshape((b, n, block) + x.shape[2:])
    return jnp.moveaxis(x, 1, 0)


def _match_vma(tree, ref):
    """Mark scan-carry inits device-varying to match a reference value's
    varying-manual-axes (required inside shard_map bodies)."""
    vma = tuple(getattr(jax.typeof(ref), "vma", ()))
    if not vma:
        return tree
    return jax.tree.map(lambda x: jax.lax.pvary(x, vma), tree)


def _flash_fwd_impl(q, k, v, q_pos, k_pos, causal, window,
                    block_q, block_kv):
    """Returns (out (B,Sq,H,hd), lse (B,Sq,H)). KV already head-repeated."""
    b, sq, h, hd = q.shape
    scale = hd ** -0.5
    qb = _blockify(q, block_q)
    qposb = _blockify(q_pos, block_q, pad_value=-1)
    kb = _blockify(k, block_kv)
    vb = _blockify(v, block_kv)
    kposb = _blockify(k_pos, block_kv, pad_value=2 ** 30)

    def q_block(carry, qi):
        qblk, qpblk = qi                                 # (B,bq,H,hd),(B,bq)

        def kv_block(state, ki):
            m, l, acc = state
            kblk, vblk, kpblk = ki
            logits = jnp.einsum("bqhd,bkhd->bhqk", qblk, kblk,
                                preferred_element_type=jnp.float32) * scale
            msk = _causal_window_mask(qpblk, kpblk, causal, window)
            msk &= (kpblk < 2 ** 29)[:, None, :] & (qpblk >= 0)[:, :, None]
            logits = jnp.where(msk[:, None], logits, NEG_INF)
            m_new = jnp.maximum(m, logits.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(logits - m_new[..., None])
            l_new = l * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p.astype(qblk.dtype), vblk
            ).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        init = _match_vma(
            (jnp.full((b, h, block_q), NEG_INF, jnp.float32),
             jnp.zeros((b, h, block_q), jnp.float32),
             jnp.zeros((b, h, block_q, hd), jnp.float32)), qblk)
        (m, l, acc), _ = lax.scan(kv_block, init, (kb, vb, kposb))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        lse = m + jnp.log(jnp.maximum(l, 1e-30))         # (B,H,bq)
        return carry, (out.transpose(0, 2, 1, 3).astype(q.dtype),
                       lse.transpose(0, 2, 1))           # (B,bq,H,*)

    _, (outs, lses) = lax.scan(q_block, None, (qb, qposb))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, -1, h, hd)[:, :sq]
    lse = jnp.moveaxis(lses, 0, 1).reshape(b, -1, h)[:, :sq]
    return out, lse


def _flash_bwd_impl(q, k, v, q_pos, k_pos, out, lse, dout, causal, window,
                    block_q, block_kv):
    """FlashAttention backward: blockwise recompute of p from (q,k,lse).
    Live memory O(block_q x block_kv) — no O(S²) residuals."""
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    scale = hd ** -0.5
    delta = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32),
                    axis=-1)                              # (B,Sq,H)

    qb = _blockify(q, block_q)
    qposb = _blockify(q_pos, block_q, pad_value=-1)
    lseb = _blockify(lse, block_q, pad_value=1.0)
    deltab = _blockify(delta, block_q)
    doutb = _blockify(dout, block_q)
    kb = _blockify(k, block_kv)
    vb = _blockify(v, block_kv)
    kposb = _blockify(k_pos, block_kv, pad_value=2 ** 30)
    nq = qb.shape[0]

    def kv_block(dq_acc, ki):
        kblk, vblk, kpblk = ki                            # (B,bkv,H,hd)

        def q_block(state, qi):
            dk, dv = state
            qblk, qpblk, lblk, deltblk, doblk, dq_i = qi
            s = jnp.einsum("bqhd,bkhd->bhqk", qblk, kblk,
                           preferred_element_type=jnp.float32) * scale
            msk = _causal_window_mask(qpblk, kpblk, causal, window)
            msk &= (kpblk < 2 ** 29)[:, None, :] & (qpblk >= 0)[:, :, None]
            p = jnp.where(msk[:, None],
                          jnp.exp(s - lblk.transpose(0, 2, 1)[..., None]),
                          0.0)                            # (B,H,bq,bkv)
            dv = dv + jnp.einsum("bhqk,bqhd->bkhd", p.astype(doblk.dtype),
                                 doblk).astype(jnp.float32)
            dp = jnp.einsum("bqhd,bkhd->bhqk", doblk, vblk,
                            preferred_element_type=jnp.float32)
            ds = p * (dp - delta_t(deltblk)[..., None]) * scale
            dq_i = dq_i + jnp.einsum("bhqk,bkhd->bqhd",
                                     ds.astype(qblk.dtype), kblk
                                     ).astype(jnp.float32)
            dk = dk + jnp.einsum("bhqk,bqhd->bkhd", ds.astype(qblk.dtype),
                                 qblk).astype(jnp.float32)
            return (dk, dv), dq_i

        def delta_t(x):                                   # (B,bq,H)→(B,H,bq)
            return x.transpose(0, 2, 1)

        init = _match_vma(
            (jnp.zeros((b, block_kv, h, hd), jnp.float32),
             jnp.zeros((b, block_kv, h, hd), jnp.float32)), kblk)
        (dk, dv), dq_new = lax.scan(
            q_block, init, (qb, qposb, lseb, deltab, doutb, dq_acc))
        return dq_new, (dk, dv)

    dq0 = _match_vma(jnp.zeros((nq, b, block_q, h, hd), jnp.float32), q)
    dq, (dks, dvs) = lax.scan(kv_block, dq0, (kb, vb, kposb))
    dq = jnp.moveaxis(dq, 0, 1).reshape(b, -1, h, hd)[:, :sq]
    dk = jnp.moveaxis(dks, 0, 1).reshape(b, -1, h, hd)[:, :sk]
    dv = jnp.moveaxis(dvs, 0, 1).reshape(b, -1, h, hd)[:, :sk]
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8))
def _flash_core(q, k, v, q_pos, k_pos, causal, window, block_q, block_kv):
    out, _ = _flash_fwd_impl(q, k, v, q_pos, k_pos, causal, window,
                             block_q, block_kv)
    return out


def _flash_core_fwd(q, k, v, q_pos, k_pos, causal, window, block_q,
                    block_kv):
    out, lse = _flash_fwd_impl(q, k, v, q_pos, k_pos, causal, window,
                               block_q, block_kv)
    return out, (q, k, v, q_pos, k_pos, out, lse)


def _flash_core_bwd(causal, window, block_q, block_kv, res, dout):
    q, k, v, q_pos, k_pos, out, lse = res
    dq, dk, dv = _flash_bwd_impl(q, k, v, q_pos, k_pos, out, lse, dout,
                                 causal, window, block_q, block_kv)
    return dq, dk, dv, None, None


_flash_core.defvjp(_flash_core_fwd, _flash_core_bwd)


def attention_flash_jnp(q, k, v, q_pos, k_pos, causal=True, window=None,
                        block_q=512, block_kv=1024):
    """Blockwise (FlashAttention-style) online-softmax attention in pure
    JAX with a custom flash BACKWARD (blockwise recompute from lse) —
    O(block_q x block_kv) live memory in both directions."""
    n_rep = q.shape[2] // k.shape[2]
    k, v = _repeat_kv(k, n_rep), _repeat_kv(v, n_rep)
    return _flash_core(q, k, v, q_pos, k_pos, causal, window,
                       min(block_q, q.shape[1]), min(block_kv, k.shape[1]))


def attention_decode(q, k_cache, v_cache, q_pos, k_pos, window=None):
    """Single-step decode attention.

    q: (B,1,H,hd); caches: (B,S,KH,hd); k_pos: (B,S) absolute positions of
    cache slots (2**30 marks empty slots — they mask out via causality).
    """
    n_rep = q.shape[2] // k_cache.shape[2]
    kh = k_cache.shape[2]
    b, s = k_cache.shape[:2]
    hd = q.shape[-1]
    scale = hd ** -0.5
    # grouped-query einsum without materializing repeated KV
    qg = q.reshape(b, 1, kh, n_rep, hd)
    logits = jnp.einsum("bqhrd,bkhd->bhrqk", qg, k_cache,
                        preferred_element_type=jnp.float32) * scale
    valid = k_pos[:, None, :] <= q_pos[:, :, None]       # (B,1,S)
    if window is not None:
        valid &= (q_pos[:, :, None] - k_pos[:, None, :]) < window
    logits = jnp.where(valid[:, None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhrqk,bkhd->bqhrd", probs, v_cache)
    return out.reshape(b, 1, kh * n_rep, hd)


def attention(q, k, v, q_pos, k_pos, *, causal=True, window=None,
              opts: ModelOptions = DEFAULT_OPTIONS):
    impl = opts.attn_impl
    if impl == "auto":
        impl = "flash_jnp" if k.shape[1] > opts.flash_threshold else "naive"
    if impl == "naive":
        return attention_naive(q, k, v, q_pos, k_pos, causal, window)
    if impl == "flash_jnp":
        return attention_flash_jnp(q, k, v, q_pos, k_pos, causal, window,
                                   opts.block_q, opts.block_kv)
    if impl == "pallas":
        from repro.kernels import ops as kops
        return kops.flash_attention(q, k, v, q_pos, k_pos, causal=causal,
                                    window=window)
    raise ValueError(f"unknown attn_impl {impl!r}")


# --------------------------------------------------------------------------
# MLPs
# --------------------------------------------------------------------------

def swiglu(x, w_gate, w_up, w_down):
    g = jnp.einsum("bsd,df->bsf", x, w_gate)
    u = jnp.einsum("bsd,df->bsf", x, w_up)
    return jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u, w_down)


def gelu_mlp(x, w1, b1, w2, b2):
    h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, w1) + b1)
    return jnp.einsum("bsf,fd->bsd", h, w2) + b2


# --------------------------------------------------------------------------
# ring attention (context parallelism)
# --------------------------------------------------------------------------

def combine_attention_partials(outs, lses):
    """Merge attention partials computed against disjoint KV shards.

    outs: list of (B,S,H,hd); lses: list of (B,S,H) log-sum-exp. The
    online-softmax identity: softmax over the union = exp-weighted
    combination of the partials. This is the math under both flash
    (sequential blocks) and ring attention (distributed blocks).
    """
    m = lses[0]
    for l in lses[1:]:
        m = jnp.maximum(m, l)
    num = jnp.zeros_like(outs[0], dtype=jnp.float32)
    den = jnp.zeros(lses[0].shape, jnp.float32)
    for o, l in zip(outs, lses):
        w = jnp.exp(l - m)
        num = num + o.astype(jnp.float32) * w[..., None]
        den = den + w
    return (num / jnp.maximum(den, 1e-30)[..., None]).astype(outs[0].dtype)


def attention_partial(q, k, v, q_pos, k_pos, causal=True, window=None,
                      block_q=512, block_kv=1024):
    """Flash attention returning (out, lse) for partial-KV combination."""
    n_rep = q.shape[2] // k.shape[2]
    k, v = _repeat_kv(k, n_rep), _repeat_kv(v, n_rep)
    return _flash_fwd_impl(q, k, v, q_pos, k_pos, causal, window,
                           min(block_q, q.shape[1]),
                           min(block_kv, k.shape[1]))


def ring_attention(q, k, v, q_pos, k_pos, axis_name: str, causal=True,
                   window=None, block_q=512, block_kv=1024):
    """Context-parallel attention: sequence sharded over `axis_name`.

    Call INSIDE shard_map with q,k,v local shards (B, S_loc, H|KH, hd)
    and q_pos/k_pos the local absolute positions. Each of the
    ring-size steps computes a flash partial against the resident KV
    shard, then rotates KV (+positions) to the next neighbour with
    collective_permute — compute and comm overlap on real hardware.
    GSPMD cannot derive this program from a sharded-sequence constraint
    (measured: mass resharding, EXPERIMENTS.md §Perf C3); shard_map
    states it explicitly.
    """
    n = jax.lax.axis_size(axis_name)
    perm = [(i, (i + 1) % n) for i in range(n)]
    # mark the rotating tensors device-varying over the ring axis (the
    # scan carry must have stable varying-manual-axes types); inputs
    # already varying (sharded over the ring) pass through unchanged
    def _vary(x):
        if axis_name in getattr(jax.typeof(x), "vma", ()):
            return x
        return jax.lax.pvary(x, (axis_name,))

    k, v, k_pos = _vary(k), _vary(v), _vary(k_pos)

    def step(carry, _):
        k_cur, v_cur, kpos_cur, outs = carry
        out, lse = attention_partial(q, k_cur, v_cur, q_pos, kpos_cur,
                                     causal, window, block_q, block_kv)
        k_nxt = jax.lax.ppermute(k_cur, axis_name, perm)
        v_nxt = jax.lax.ppermute(v_cur, axis_name, perm)
        kpos_nxt = jax.lax.ppermute(kpos_cur, axis_name, perm)
        return (k_nxt, v_nxt, kpos_nxt, None), (out, lse)

    (_, _, _, _), (outs, lses) = lax.scan(
        step, (k, v, k_pos, None), None, length=n)
    return combine_attention_partials(
        [outs[i] for i in range(n)], [lses[i] for i in range(n)])
