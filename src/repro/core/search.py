"""Use-case: automatic hybrid-parallel strategy search (paper §6).

Compatibility surface over :mod:`repro.search` — the subsystem that
adds a shared profile cache, dominance pruning, and multi-cluster
Pareto search. ``grid_search`` keeps the seed signature and behavior
(every candidate fully simulated, one provider, full sorted ranking
with OOM entries included) so existing callers and the cached-vs-naive
cross-check tests keep working.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

from repro.configs.base import ArchConfig
from repro.core.costmodel import V5E_POD
from repro.core.profiler import AnalyticalProvider, Provider
from repro.search.cache import ProfileCache
from repro.search.engine import SearchEngine, SearchEntry
from repro.search.prune import estimate_memory, memory_feasible

__all__ = ["SearchEntry", "grid_search", "memory_feasible",
           "estimate_memory"]


def grid_search(cfg: ArchConfig, n_devices: int, global_batch: int,
                seq: int, provider: Optional[Provider] = None,
                microbatches: Optional[Sequence[int]] = None,
                schedules: Sequence[str] = ("1f1b",),
                check_memory: bool = False) -> List[SearchEntry]:
    provider = provider or AnalyticalProvider(V5E_POD)
    engine = SearchEngine(cfg, cache=ProfileCache.from_provider(provider),
                          prune=False, check_memory=check_memory)
    result = engine.search(n_devices, global_batch, seq,
                           microbatches=microbatches, schedules=schedules)
    return result.entries
