"""Paper-fidelity validation sweep entry point (CI: validate-smoke job).

Runs predict() vs multi-seed replay() over the accuracy matrix, writes
``validation_report.json`` (uploaded as a CI artifact), prints the
pass/fail table, and exits non-zero if any non-xfail cell exceeds the
paper's §5 thresholds.

    PYTHONPATH=src python benchmarks/bench_validate.py --smoke
    PYTHONPATH=src python benchmarks/bench_validate.py --full --seeds 0,1,2,3
    PYTHONPATH=src python benchmarks/bench_validate.py --update-goldens
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import sys
import time

from repro.validate import (Thresholds, full_matrix, run_sweep,
                            smoke_matrix)
from repro.validate.report import format_validation_report, save

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), os.pardir, "tests",
                           "goldens", "validation_smoke.json")


def main() -> None:
    ap = argparse.ArgumentParser()
    matrix = ap.add_mutually_exclusive_group()
    matrix.add_argument("--smoke", action="store_true",
                        help="CI matrix (models x schedules x strategies;"
                             " the default)")
    matrix.add_argument("--full", action="store_true",
                        help="nightly-scale cross product")
    ap.add_argument("--seeds", default="0,1,2",
                    help="comma-separated replay seeds")
    ap.add_argument("--cluster", default="a40-cluster")
    ap.add_argument("--jitter", type=float, default=0.025,
                    help="replay per-event jitter sigma")
    ap.add_argument("--batch-time-threshold", type=float, default=None)
    ap.add_argument("--activity-threshold", type=float, default=None)
    ap.add_argument("--out", default="validation_report.json",
                    help="report path ('' to skip writing)")
    ap.add_argument("--update-goldens", action="store_true",
                    help=f"rewrite {os.path.normpath(GOLDEN_PATH)}")
    ap.add_argument("--sequential", action="store_true",
                    help="legacy one-replay-per-seed path with "
                         "materialized-activity metrics (A/B baseline; "
                         "the default is one batched replay per cell)")
    args = ap.parse_args()
    if args.update_goldens and (
            args.full or args.seeds != "0,1,2"
            or args.cluster != "a40-cluster" or args.jitter != 0.025
            or args.batch_time_threshold is not None
            or args.activity_threshold is not None):
        ap.error("--update-goldens pins the smoke matrix with default "
                 "seeds/cluster/jitter/thresholds — tests/"
                 "test_validation.py hard-codes them; drop the overrides")

    cells = full_matrix() if args.full else smoke_matrix()
    seeds = tuple(int(s) for s in args.seeds.split(","))
    thr = Thresholds()
    if args.batch_time_threshold is not None:
        thr = dataclasses.replace(
            thr, batch_time=args.batch_time_threshold,
            batch_time_worst=1.5 * args.batch_time_threshold)
    if args.activity_threshold is not None:
        thr = dataclasses.replace(thr, activity=args.activity_threshold)

    t0 = time.perf_counter()
    result = run_sweep(cells, cluster=args.cluster, seeds=seeds,
                       thresholds=thr, jitter_sigma=args.jitter,
                       batched=not args.sequential)
    wall = time.perf_counter() - t0

    print(format_validation_report(result))
    print(f"\nswept {len(result.cells)} cells x {len(seeds)} seeds "
          f"in {wall:.2f}s ({len(result.cells) / wall:.1f} cells/s, "
          f"{'sequential replay' if args.sequential else 'batched replay'})")

    if args.update_goldens:
        path = os.path.normpath(GOLDEN_PATH)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        save(result, path)
        print(f"goldens written to {path}")
    if args.out:
        save(result, args.out)
        print(f"report written to {args.out}")

    if not result.passed:
        fails = ", ".join(c.cell.label() for c in result.failures)
        print(f"validate/ERROR: thresholds exceeded on {fails}",
              file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
