"""dbrx-132b [moe] — 16 experts top-4, fine-grained.

40L d_model=6144 48H (GQA kv=8) d_ff=10752(per-expert) vocab=100352, MoE 16e top-4
[hf:databricks/dbrx-base; unverified]

long_500k skipped: full attention (see DESIGN.md §4).
"""
from repro.configs.base import ArchConfig, MoEConfig, register

CONFIG = register(ArchConfig(
    name="dbrx_132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=10752,
    vocab=100352,
    rope_theta=5e5,
    moe=MoEConfig(n_experts=16, top_k=4, d_ff_expert=10752),
    shapes=("train_4k", "prefill_32k", "decode_32k"),
    source="hf:databricks/dbrx-base; unverified",
))
