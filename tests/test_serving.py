"""Serving-scenario gate: Prefill/Decode event graphs through the same
predict-vs-replay machinery as training, serve()/serve_batch() answers
bit-identical to per-engine simulate() (including from a warm store in
a fresh process), and the scenario serde/content-address surfaces.
"""
import dataclasses
import json
import os
import subprocess
import sys

import pytest

import repro.core  # noqa: F401  — establishes the package import order
from repro.core import A40_CLUSTER, AnalyticalProvider, DistSim
from repro.core.modelgraph import kv_cache_bytes
from repro.core.scenario import (TRAIN, Decode, Prefill, Scenario,
                                 TrainStep, scenario_from_dict)
from repro.core.events import Strategy
from repro.store import ServeQuery
from repro.validate import (CellMetrics, run_sweep, serving_matrix,
                            smoke_matrix)
from repro.validate.report import dump, dumps, load, load_path

GOLDEN = os.path.join(os.path.dirname(__file__), "goldens",
                      "validation_serving.json")
MATRIX = serving_matrix()
SEEDS = (0, 1, 2)


def _provider():
    return AnalyticalProvider(A40_CLUSTER)


# --------------------------------------------------------------------------
# scenario objects: serde, hashing, derivation hooks
# --------------------------------------------------------------------------

@pytest.mark.parametrize("sc", [
    TRAIN, TrainStep(), Prefill(), Decode(),
    Decode(steps=4, context=4096),
    Decode(steps=3, arrivals=(0.0, 1e-4, 2e-4)),
])
def test_scenario_roundtrip(sc):
    back = scenario_from_dict(json.loads(json.dumps(sc.to_dict())))
    assert back == sc
    assert hash(back) == hash(sc)


def test_scenario_from_dict_defaults_and_errors():
    assert scenario_from_dict(None) == TRAIN     # pre-scenario reports
    assert scenario_from_dict(Decode()) == Decode()
    with pytest.raises(ValueError, match="unknown scenario kind"):
        scenario_from_dict({"kind": "finetune"})
    with pytest.raises(ValueError, match="steps"):
        Decode(steps=0)


def test_scenario_derivation_hooks():
    strat = Strategy(mp=1, pp=2, dp=2, microbatches=4)
    assert TRAIN.microbatch_size(strat, 16) == 2   # gb/(dp*m)
    assert TRAIN.task_count(strat) == 4
    assert TRAIN.kv_len(512) == 0
    d = Decode(steps=8, context=4096, arrivals=(0.0, 1e-4))
    assert d.microbatch_size(strat, 16) == 8       # slots = gb/dp
    assert d.task_count(strat) == 8
    assert d.tokens(16, 512) == 16 * 8             # one token/slot/step
    assert d.kv_len(512) == 4096
    assert Decode(steps=8).kv_len(512) == 512
    # stripped: what an EngineBuild (and its store address) depends on
    assert d.stripped() == Decode(steps=1, context=4096)
    assert Prefill().stripped() == Prefill()
    assert d.label() == "decode8@4096"


def test_engine_rejects_mismatched_scenario():
    """A build compiled for decode cannot silently serve a train
    engine (the event means differ) — the engine refuses."""
    from repro.core.engine import EngineBuild, EventFlowEngine
    cell = next(c for c in MATRIX if c.scenario.kind == "decode")
    provider = _provider()
    sim = DistSim(cell.config(), cell.strategy, cell.global_batch,
                  cell.seq, provider, scenario=cell.scenario)
    build = EngineBuild(sim.positions(), cell.strategy, provider,
                        scenario=cell.scenario)
    with pytest.raises(ValueError):
        EventFlowEngine(build.stages, cell.strategy, provider,
                        build=build, scenario=TRAIN)


# --------------------------------------------------------------------------
# accuracy: the serving matrix gates at the paper thresholds + goldens
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def sweep():
    return run_sweep(MATRIX, cluster=A40_CLUSTER, seeds=SEEDS)


@pytest.mark.parametrize("label", [c.label() for c in MATRIX])
def test_serving_cell_within_paper_targets(sweep, label):
    res = {c.cell.label(): c for c in sweep.cells}[label]
    m = res.metrics
    assert m.batch_time_error <= 0.04, (label, m.batch_time_error)
    assert m.activity_error_max <= 0.05, (label, m.activity_error_max)
    assert res.passed, (label, res.violations)


def test_serving_goldens_match(sweep):
    golden = load_path(GOLDEN)
    assert golden.passed
    cur = {c.cell.label(): c for c in sweep.cells}
    gold = {c.cell.label(): c for c in golden.cells}
    assert set(cur) == set(gold)
    for label, g in gold.items():
        c = cur[label]
        assert c.cell == g.cell          # incl. the scenario field
        assert c.pred_batch_time == g.pred_batch_time
        assert c.replay_batch_times == g.replay_batch_times
        for f in dataclasses.fields(CellMetrics):
            assert getattr(c.metrics, f.name) == pytest.approx(
                getattr(g.metrics, f.name), rel=1e-6, abs=1e-9), \
                (label, f.name)


def test_serving_report_roundtrip(sweep):
    assert load(dump(sweep)) == sweep
    assert load(dumps(sweep)) == sweep


def test_training_report_has_no_scenario_key():
    """Training cells must serialize exactly as before the scenario
    axis existed — the committed training goldens stay byte-valid."""
    res = run_sweep(smoke_matrix()[:1], cluster=A40_CLUSTER, seeds=(0,))
    d = dump(res)
    assert "scenario" not in d["cells"][0]
    sd = dump(run_sweep(MATRIX[:1], cluster=A40_CLUSTER, seeds=(0,)))
    assert sd["cells"][0]["scenario"]["kind"] == "prefill"


# --------------------------------------------------------------------------
# serve()/serve_batch(): bit-identity with per-engine simulate()
# --------------------------------------------------------------------------

def _queries(cells):
    return [ServeQuery(c.arch, c.strategy, global_batch=c.global_batch,
                       seq=c.seq, smoke=c.smoke, scenario=c.scenario)
            for c in cells]


def test_serve_batch_matches_simulate_per_scenario(tmp_path):
    answers = DistSim.serve_batch(_queries(MATRIX), str(tmp_path))
    for c, a in zip(MATRIX, answers):
        sim = DistSim(c.config(), c.strategy, c.global_batch, c.seq,
                      _provider(), scenario=c.scenario)
        r = sim.simulate()
        assert a.batch_time == r.batch_time, c.label()
        assert a.throughput_tokens == r.throughput_tokens(), c.label()
        if c.scenario.kind == "decode":
            # tokens/sec numerator is slots * steps, not gb * seq
            assert a.throughput_tokens == pytest.approx(
                c.global_batch * c.scenario.task_count(c.strategy)
                / a.batch_time)
            assert a.kv_cache_bytes > 0
        else:
            assert a.kv_cache_bytes == 0.0


def test_serve_decode_kv_headroom(tmp_path):
    c = next(c for c in MATRIX if c.scenario.kind == "decode"
             and c.scenario.context)
    [a] = DistSim.serve_batch(_queries([c]), str(tmp_path))
    micro = c.scenario.microbatch_size(c.strategy, c.global_batch)
    expect = kv_cache_bytes(c.config(), micro,
                            c.scenario.kv_len(c.seq)) \
        / (c.strategy.mp * c.strategy.pp)
    assert a.kv_cache_bytes == expect
    assert a.mem_bytes > a.kv_cache_bytes
    assert a.feasible and a.hbm_headroom > 0


def test_serve_query_scenario_roundtrip():
    q = _queries(MATRIX)[1]
    assert ServeQuery.from_dict(json.loads(json.dumps(q.to_dict()))) == q


def test_warm_store_fresh_process_bit_identical(tmp_path):
    """Acceptance: serve(scenario=decode) tokens/sec from a WARM store
    in a FRESH python process equals per-engine simulate() here."""
    cells = [c for c in MATRIX if c.scenario.kind == "decode"][:2]
    queries = _queries(cells)
    DistSim.serve_batch(queries, str(tmp_path))      # warm the store
    expected = []
    for c in cells:
        r = DistSim(c.config(), c.strategy, c.global_batch, c.seq,
                    _provider(), scenario=c.scenario).simulate()
        expected.append((r.batch_time, r.throughput_tokens()))

    src = os.path.abspath(os.path.join(
        os.path.dirname(repro.core.__file__), "..", ".."))
    child = (
        "import json, sys\n"
        "sys.path.insert(0, sys.argv[1])\n"
        "import repro.core\n"
        "from repro.core import DistSim\n"
        "from repro.store import ServeQuery\n"
        "qs = [ServeQuery.from_dict(d) for d in json.loads(sys.argv[3])]\n"
        "server = DistSim.serve(sys.argv[2])\n"
        "ans = server.answer_batch(qs)\n"
        "snap = server.snapshot()\n"
        "json.dump({'bt': [a.batch_time for a in ans],\n"
        "           'tok': [a.throughput_tokens for a in ans],\n"
        "           'evals': sum(c['evaluations'] for c in\n"
        "                        snap['clusters'].values())},\n"
        "          sys.stdout)\n")
    out = subprocess.run(
        [sys.executable, "-c", child, src, str(tmp_path),
         json.dumps([q.to_dict() for q in queries])],
        capture_output=True, text=True)
    assert out.returncode == 0, out.stderr
    got = json.loads(out.stdout)
    assert got["evals"] == 0               # everything from the store
    assert got["bt"] == [bt for bt, _ in expected]
    assert got["tok"] == [tok for _, tok in expected]
