"""DistSim core behaviour tests (paper §3-§5)."""
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core import (A40_CLUSTER, AnalyticalProvider, DistSim, Strategy,
                        activity_error, batch_time_error)
from repro.core.events import (Strategy, build_stage_events, flatten_layers,
                               partition_stages, unique_events)


@pytest.fixture(scope="module")
def provider():
    return AnalyticalProvider(A40_CLUSTER)


CFG = get_config("bert_large")


def make_sim(provider, mp=2, pp=2, dp=2, m=4, schedule="1f1b", gb=16):
    return DistSim(CFG, Strategy(mp=mp, pp=pp, dp=dp, microbatches=m,
                                 schedule=schedule), gb, 512, provider)


def test_event_dedup_reduces_profiling(provider):
    """Observation 1: unique events ≪ total instances (Table 3)."""
    sim = make_sim(provider)
    rep = sim.profiling_report()
    assert rep["unique_events"] < rep["total_instances"] / 10
    assert rep["relative_scale"] < 0.5          # paper: 0.1296


def test_events_hashable_and_deduped(provider):
    stages = build_stage_events(CFG, Strategy(mp=2, pp=2, dp=2,
                                              microbatches=4), 2, 512, 8)
    counts = unique_events(stages, Strategy(mp=2, pp=2, dp=2,
                                            microbatches=4), 8)
    for e, c in counts.items():
        assert c >= 1
        assert hash(e) == hash(e)


def test_stage_partition_balanced():
    layers = flatten_layers(CFG, 2, 512)
    for pp in (1, 2, 4, 8):
        stages = partition_stages(layers, pp)
        assert len(stages) == pp
        assert sum(len(s.layers) for s in stages) == len(layers)
        flops = [sum(l.fwd_flops for l in s.layers) for s in stages]
        assert max(flops) < 2.5 * (sum(flops) / pp)


def test_predict_matches_replay_batch_time(provider):
    """§5.2: <4% batch-time error across strategies."""
    for mp, pp, dp, m in [(1, 1, 4, 1), (1, 2, 2, 4), (2, 2, 1, 4),
                          (2, 2, 4, 4), (1, 4, 1, 8)]:
        sim = make_sim(provider, mp, pp, dp, m)
        pred = sim.simulate().result()
        act = sim.simulate(seeds=0).result()
        err = batch_time_error(pred.timeline, act.timeline)
        assert err < 0.04, f"{mp}M{pp}P{dp}D err={err:.3f}"


def test_predict_matches_replay_activity(provider):
    """§5.3: <5% per-device activity error."""
    sim = make_sim(provider, 2, 2, 2, 4)
    pred = sim.simulate().result()
    act = sim.simulate(seeds=3).result()
    errs = activity_error(pred.timeline, act.timeline)
    assert errs and max(errs.values()) < 0.05


def test_mp_devices_identical(provider):
    """§5.4 observation: MP rank pairs show the same activity."""
    sim = make_sim(provider, mp=2, pp=2, dp=1, m=4)
    tl = sim.simulate().result().timeline
    by_dev = tl.by_device()
    for d in range(0, tl.n_devices, 2):
        a = [(x.name, round(x.start, 9)) for x in by_dev[d]
             if x.kind in ("F", "B")]
        b = [(x.name, round(x.start, 9)) for x in by_dev[d + 1]
             if x.kind in ("F", "B")]
        assert a == b


def test_more_microbatches_fewer_bubbles(provider):
    frac = []
    for m in (2, 4, 8, 16):
        sim = make_sim(provider, mp=1, pp=4, dp=1, m=m, gb=16)
        frac.append(sim.simulate().result().bubble_fraction)
    assert frac[-1] < frac[0]


def test_schedule_ordering_1f1b_beats_gpipe(provider):
    g = make_sim(provider, 1, 4, 1, 8, "gpipe").simulate().result()
    d = make_sim(provider, 1, 4, 1, 8, "1f1b").simulate().result()
    assert d.batch_time <= g.batch_time * 1.02


def test_dp_scaling_increases_throughput(provider):
    t1 = DistSim(CFG, Strategy(dp=1, microbatches=1), 8, 512,
                 provider).simulate().result()
    t4 = DistSim(CFG, Strategy(dp=4, microbatches=1), 8, 512,
                 provider).simulate().result()
    assert t4.batch_time < t1.batch_time


def test_allreduce_extrapolation_small_error(provider):
    """§4.2: ≤8-way profile extrapolated to N — <2% effect on the ring
    formula (exact here by construction; checks the code path)."""
    from repro.core.events import Event
    e64 = Event(kind="collective", name="x", coll_op="all_reduce",
                nbytes=1e8, n_dev=64, scope="inter")
    t_extrap = provider.time(e64)
    from repro.core.costmodel import collective_time
    t_direct = collective_time("all_reduce", 1e8, 64, provider.cluster,
                               "inter")
    assert abs(t_extrap - t_direct) / t_direct < 0.02


def test_invalid_batch_raises(provider):
    with pytest.raises(ValueError):
        DistSim(CFG, Strategy(dp=3, microbatches=5), 16, 512, provider)


def test_zero1_changes_sync_events(provider):
    a = DistSim(CFG, Strategy(dp=4, microbatches=1), 16, 512,
                provider).simulate().result()
    b = DistSim(CFG, Strategy(dp=4, microbatches=1, zero1=True), 16, 512,
                provider).simulate().result()
    assert abs(a.batch_time - b.batch_time) / a.batch_time < 0.5
    assert a.batch_time != b.batch_time


def test_chrome_trace_export(tmp_path, provider):
    import json
    from repro.core.timeline import to_chrome_trace
    sim = make_sim(provider, 1, 2, 2, 4)
    tl = sim.simulate().result().timeline
    path = str(tmp_path / "trace.json")
    to_chrome_trace(tl, path)
    data = json.load(open(path))
    evs = [e for e in data["traceEvents"] if e["ph"] == "X"]
    assert len(evs) == len(tl.activities)
    assert all(e["dur"] >= 0 for e in evs)


def test_pipedream_schedule_no_sync(provider):
    """Async pipeline (paper §7): no DP all-reduce events."""
    s_sync = Strategy(pp=2, dp=2, microbatches=4)
    s_async = Strategy(pp=2, dp=2, microbatches=4, schedule="pipedream")
    tl_sync = DistSim(CFG, s_sync, 8, 512, provider).simulate().result().timeline
    tl_async = DistSim(CFG, s_async, 8, 512, provider).simulate().result().timeline
    assert any(a.kind == "AR" for a in tl_sync.activities)
    assert not any(a.kind == "AR" for a in tl_async.activities)
    assert tl_async.batch_time <= tl_sync.batch_time


def test_grad_compression_whatif(provider):
    """Compression shrinks the DP sync event; DP-bound strategies gain."""
    a = DistSim(CFG, Strategy(dp=8, microbatches=1), 16, 512,
                provider).simulate().result()
    b = DistSim(CFG, Strategy(dp=8, microbatches=1, grad_compress=0.25),
                16, 512, provider).simulate().result()
    assert b.batch_time < a.batch_time


# --------------------------------------------------------------------------
# one simulate() surface + deprecated wrappers (PR: api_redesign)
# --------------------------------------------------------------------------

def test_simulate_predict_and_replay_lanes(provider):
    """simulate() is the whole surface: seeds=None -> zero-noise predict
    lane; seeds=... -> replay lanes, bit-identical to sequential runs."""
    sim = make_sim(provider)
    pred = sim.simulate()
    assert len(pred) == 1 and pred.seeds == [None]
    assert pred.batch_time == sim.engine().run().batch_time
    rep = sim.simulate(seeds=(0, 1, 2))
    assert len(rep) == 3 and rep.seeds == [0, 1, 2]
    for i, s in enumerate((0, 1, 2)):
        tl = sim.engine().run(jitter_sigma=0.025, seed=s)
        assert float(rep.batch_times[i]) == tl.batch_time
    # int seeds means one replay lane, not a seed count
    one = sim.simulate(seeds=1)
    assert one.seeds == [1]
    with pytest.raises(ValueError):
        rep.batch_time                 # ambiguous across 3 lanes
    assert rep.result(2).batch_time == float(rep.batch_times[2])
    assert len(rep.results()) == 3
    assert rep.utilization().shape[0] == 3
    assert rep.bubble_fraction().shape == (3,)


def test_deprecated_wrappers_warn_and_match_simulate(provider):
    """Each legacy entry point warns once and returns exactly what the
    simulate() lane it wraps returns."""
    sim = make_sim(provider)
    with pytest.warns(DeprecationWarning, match="predict"):
        pred = sim.predict()
    assert pred.batch_time == sim.simulate().batch_time
    with pytest.warns(DeprecationWarning, match="replay"):
        act = sim.replay(seed=3)
    assert act.batch_time == sim.simulate(seeds=3).result().batch_time
    with pytest.warns(DeprecationWarning, match="predict_batched"):
        pb = sim.predict_batched()
    assert float(pb.batch_times[0]) == pred.batch_time
    with pytest.warns(DeprecationWarning, match="replay_batched"):
        rb = sim.replay_batched((0, 1))
    ref = sim.simulate(seeds=(0, 1)).batch
    assert np.array_equal(rb.batch_times, ref.batch_times)
    with pytest.warns(DeprecationWarning, match="predict_and_replay"):
        pr, (a0,) = sim.predict_and_replay(seeds=(0,))
    assert pr.batch_time == pred.batch_time
    assert a0.batch_time == sim.simulate(seeds=0).result().batch_time
