"""Decoder-only LM assembly: dense / GQA / SWA / MoE / SSM / hybrid / VLM.

Layer weights are STACKED over the layer axis and iterated with
``lax.scan`` — this keeps the HLO size O(1) in depth (critical for the
88-layer 123B dry-run) and gives XLA a single loop body to optimize.

Public entry points (used by api.py):
  init_params(cfg, key, opts)            → parameter pytree
  forward(cfg, params, batch, opts)      → logits (train / prefill)
  loss_fn(cfg, params, batch, opts)      → scalar loss (chunked CE)
  init_cache(cfg, batch, max_seq, opts)  → decode cache pytree
  decode_step(cfg, params, cache, batch, opts) → (logits, new cache)
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S
from repro.models.layers import ModelOptions, DEFAULT_OPTIONS

Params = Dict[str, Any]


# --------------------------------------------------------------------------
# parameter construction
# --------------------------------------------------------------------------

def _attn_shapes(cfg: ArchConfig):
    d, hd = cfg.d_model, cfg.head_dim
    sh = {
        "ln": (d,),
        "wq": (d, cfg.n_heads * hd),
        "wk": (d, cfg.n_kv_heads * hd),
        "wv": (d, cfg.n_kv_heads * hd),
        "wo": (cfg.n_heads * hd, d),
    }
    if cfg.qkv_bias:
        sh.update(bq=(cfg.n_heads * hd,), bk=(cfg.n_kv_heads * hd,),
                  bv=(cfg.n_kv_heads * hd,))
    return sh


def _ffn_shapes(cfg: ArchConfig, use_moe: bool = True):
    d = cfg.d_model
    if cfg.moe is not None and use_moe:
        return {"ln": (d,), **M.moe_params_shape(d, cfg.moe)}
    if cfg.mlp_gelu:
        return {"ln": (d,), "w1": (d, cfg.d_ff), "b1": (cfg.d_ff,),
                "w2": (cfg.d_ff, d), "b2": (d,)}
    return {"ln": (d,), "w_gate": (d, cfg.d_ff), "w_up": (d, cfg.d_ff),
            "w_down": (cfg.d_ff, d)}


def _ssm_shapes(cfg: ArchConfig):
    return {"ln": (cfg.d_model,), **S.ssm_params_shape(cfg.d_model, cfg.ssm)}


def hybrid_ssm_split(cfg: ArchConfig):
    """(n_ssm_moe, n_ssm_dense) per hybrid period.

    A period has `hybrid_period` layers: 1 attention (which takes the MoE
    FFN when the period offset is MoE-aligned — true for jamba) and the
    rest SSM. MoE hits every `moe_period`-th FFN.
    """
    per = cfg.hybrid_period
    n_ssm = per - 1
    if cfg.moe is None:
        return 0, n_ssm
    n_moe_total = per // cfg.moe_period
    n_ssm_moe = max(0, n_moe_total - 1)        # attn layer takes one MoE slot
    return n_ssm_moe, n_ssm - n_ssm_moe


def block_shapes(cfg: ArchConfig) -> Dict[str, Dict]:
    """Per-layer-kind parameter shape trees (unstacked)."""
    out = {}
    if cfg.family == "ssm":
        out["ssm"] = _ssm_shapes(cfg)
    elif cfg.hybrid_period:
        n_moe, n_dense = hybrid_ssm_split(cfg)
        out["attn"] = {**_attn_shapes(cfg), "ffn": _ffn_shapes(cfg)}
        if n_moe:
            out["ssm_moe"] = {**_ssm_shapes(cfg),
                              "ffn": _ffn_shapes(cfg, use_moe=True)}
        if n_dense:
            out["ssm_dense"] = {**_ssm_shapes(cfg),
                                "ffn": _ffn_shapes(cfg, use_moe=False)}
    else:
        out["attn"] = {**_attn_shapes(cfg), "ffn": _ffn_shapes(cfg)}
    return out


def _stack_counts(cfg: ArchConfig):
    """How many stacked copies of each block kind."""
    if cfg.family == "ssm":
        return {"ssm": (cfg.n_layers,)}
    if cfg.hybrid_period:
        n_per = cfg.n_layers // cfg.hybrid_period
        n_moe, n_dense = hybrid_ssm_split(cfg)
        out = {"attn": (n_per,)}
        if n_moe:
            out["ssm_moe"] = (n_per, n_moe)
        if n_dense:
            out["ssm_dense"] = (n_per, n_dense)
        return out
    return {"attn": (cfg.n_layers,)}


def _init_leaf(key, shape, dtype, scale=0.02):
    if len(shape) == 1:
        # norms/biases: scales → 1, biases → 0 (heuristic: names handled above)
        return jnp.zeros(shape, dtype)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def _init_tree(key, shapes, dtype, prefix=()):
    out = {}
    names = sorted(shapes)
    keys = jax.random.split(key, len(names))
    for k, name in zip(keys, names):
        v = shapes[name]
        if isinstance(v, dict):
            out[name] = _init_tree(k, v, dtype, prefix + (name,))
        else:
            leaf = _init_leaf(k, v, dtype)
            if name in ("ln", "norm_scale") or name.startswith("ln"):
                leaf = jnp.ones(v, dtype)
            if name == "dt_bias":
                leaf = jnp.log(jnp.expm1(
                    jnp.linspace(1e-3, 0.1, v[0]))).astype(dtype)
            if name == "A_log":
                leaf = jnp.log(jnp.linspace(1.0, 16.0, v[0])).astype(dtype)
            if name == "D":
                leaf = jnp.ones(v, dtype)
            out[name] = leaf
    return out


def init_params(cfg: ArchConfig, key: jax.Array,
                opts: ModelOptions = DEFAULT_OPTIONS) -> Params:
    dtype = opts.dtype
    kemb, khead, kfin, *kblocks = jax.random.split(key, 8)
    params: Params = {
        "embed": (jax.random.normal(kemb, (cfg.vocab, cfg.d_model),
                                    jnp.float32) * 0.02).astype(dtype),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["head"] = (jax.random.normal(
            khead, (cfg.d_model, cfg.vocab), jnp.float32) * 0.02).astype(dtype)

    shapes = block_shapes(cfg)
    counts = _stack_counts(cfg)
    for i, (kind, stack) in enumerate(sorted(counts.items())):
        base = _init_tree(kblocks[i], shapes[kind], dtype)
        for n in reversed(stack):
            base = jax.tree.map(
                lambda x, n=n: jnp.broadcast_to(x, (n,) + x.shape).copy(), base)
        params[f"{kind}_layers"] = base
    return params


def param_shapes(cfg: ArchConfig, opts: ModelOptions = DEFAULT_OPTIONS):
    """ShapeDtypeStruct pytree without allocating (for the dry-run)."""
    return jax.eval_shape(
        lambda: init_params(cfg, jax.random.PRNGKey(0), opts))


# --------------------------------------------------------------------------
# blocks (forward)
# --------------------------------------------------------------------------

def _attn_block(cfg, p, x, positions, opts, causal=True,
                kv: Optional[tuple] = None):
    """Pre-norm attention with residual. kv: optional (k_src, k_pos) for
    cross-attention (enc-dec)."""
    h = L.rmsnorm(x, p["ln"])
    q = jnp.einsum("bsd,de->bse", h, p["wq"])
    src = kv[0] if kv is not None else h
    k = jnp.einsum("bsd,de->bse", src, p["wk"])
    v = jnp.einsum("bsd,de->bse", src, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    b, sq = q.shape[:2]
    sk = k.shape[1]
    hd = cfg.head_dim
    q = L.constrain_qkv(q.reshape(b, sq, cfg.n_heads, hd), opts)
    k = L.constrain_qkv(k.reshape(b, sk, cfg.n_kv_heads, hd), opts,
                        is_kv=True)
    v = L.constrain_qkv(v.reshape(b, sk, cfg.n_kv_heads, hd), opts,
                        is_kv=True)
    if kv is None:
        k_pos = positions
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
    else:
        k_pos = kv[1]
    o = L.attention(q, k, v, positions, k_pos, causal=causal,
                    window=cfg.sliding_window if kv is None else None,
                    opts=opts)
    o = L.constrain_qkv(o, opts)
    o = jnp.einsum("bse,ed->bsd", o.reshape(b, sq, cfg.n_heads * hd), p["wo"])
    # pin the row-parallel output to the residual sharding BEFORE the
    # add: turns the partial-sum all-reduce into a reduce-scatter
    # (Megatron-SP; §Perf C2 — 2x less wire bytes per layer)
    o = L.constrain(o, opts)
    return x + o


def _ffn_block(cfg, p, x, opts):
    h = L.rmsnorm(x, p["ln"])
    aux = jnp.zeros((), jnp.float32)
    if "router" in p:                       # MoE FFN
        y, aux = M.moe_ffn(h, p, cfg.moe, opts.moe_impl, opts)
    elif "w1" in p:                         # GELU MLP
        y = L.gelu_mlp(h, p["w1"], p["b1"], p["w2"], p["b2"])
    else:                                   # SwiGLU
        y = L.swiglu(h, p["w_gate"], p["w_up"], p["w_down"])
    # reduce-scatter (not all-reduce) the row-parallel output (§Perf C2)
    y = L.constrain(y, opts)
    return x + y, aux


def _ssm_layer(cfg, p, x, opts):
    h = L.rmsnorm(x, p["ln"])
    sp = {k: v for k, v in p.items() if k not in ("ln", "ffn")}
    x = x + S.ssm_block(h, sp, cfg.ssm)
    aux = jnp.zeros((), jnp.float32)
    if "ffn" in p:
        x, aux = _ffn_block(cfg, p["ffn"], x, opts)
    return x, aux


def _attn_layer(cfg, p, x, positions, opts, causal=True):
    pa = {k: v for k, v in p.items() if k != "ffn"}
    x = _attn_block(cfg, pa, x, positions, opts, causal=causal)
    x, aux = _ffn_block(cfg, p["ffn"], x, opts)
    return x, aux


# --------------------------------------------------------------------------
# backbone forward (train / prefill)
# --------------------------------------------------------------------------

def backbone(cfg: ArchConfig, params: Params, x: jax.Array,
             positions: jax.Array, opts: ModelOptions,
             causal: bool = True) -> tuple:
    """Stacked-layer scan. x: (B,S,d) → (B,S,d), aux loss."""

    if cfg.family == "ssm":
        def body(carry, lp):
            h, aux = carry
            h, a = _ssm_layer(cfg, lp, h, opts)
            return (L.constrain(h, opts), aux + a), None
        body_fn = jax.checkpoint(body) if opts.remat else body
        (x, aux), _ = lax.scan(body_fn, (x, jnp.zeros((), jnp.float32)),
                               params["ssm_layers"])
        return x, aux

    if cfg.hybrid_period:
        def period(carry, lp):
            h, aux = carry
            h, a = _attn_layer(cfg, lp["attn"], h, positions, opts, causal)
            aux = aux + a

            def inner(c, sp):
                hh, ax = c
                hh, a2 = _ssm_layer(cfg, sp, hh, opts)
                return (hh, ax + a2), None

            for kind in ("ssm_moe", "ssm_dense"):
                if kind in lp:
                    (h, aux), _ = lax.scan(inner, (h, aux), lp[kind])
            return (L.constrain(h, opts), aux), None

        stacked = {"attn": params["attn_layers"]}
        for kind in ("ssm_moe", "ssm_dense"):
            if f"{kind}_layers" in params:
                stacked[kind] = params[f"{kind}_layers"]
        body_fn = jax.checkpoint(period) if opts.remat else period
        (x, aux), _ = lax.scan(body_fn, (x, jnp.zeros((), jnp.float32)),
                               stacked)
        return x, aux

    def body(carry, lp):
        h, aux = carry
        h, a = _attn_layer(cfg, lp, h, positions, opts, causal)
        return (L.constrain(h, opts), aux + a), None
    body_fn = jax.checkpoint(body) if opts.remat else body
    (x, aux), _ = lax.scan(body_fn, (x, jnp.zeros((), jnp.float32)),
                           params["attn_layers"])
    return x, aux


def embed_inputs(cfg: ArchConfig, params: Params, batch: Dict[str, jax.Array],
                 opts: ModelOptions):
    """tokens (+ optional stub modality embeddings) → (B,S,d), positions."""
    parts = []
    if cfg.vision_stub and "patch_embeds" in batch:
        parts.append(batch["patch_embeds"].astype(opts.dtype))
    if cfg.audio_stub and "frame_embeds" in batch:
        parts.append(batch["frame_embeds"].astype(opts.dtype))
    if "tokens" in batch:
        parts.append(params["embed"][batch["tokens"]])
    x = jnp.concatenate(parts, axis=1) if len(parts) > 1 else parts[0]
    b, s = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    return x, positions


def forward(cfg: ArchConfig, params: Params, batch: Dict[str, jax.Array],
            opts: ModelOptions = DEFAULT_OPTIONS) -> jax.Array:
    """Full forward to logits (B,S,V)."""
    x, positions = embed_inputs(cfg, params, batch, opts)
    x, _ = backbone(cfg, params, x, positions, opts)
    x = L.rmsnorm(x, params["final_norm"])
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    return jnp.einsum("bsd,dv->bsv", x, head)


def _chunked_ce(x: jax.Array, head: jax.Array, labels: jax.Array,
                chunk: int = 512) -> jax.Array:
    """Cross-entropy without materializing (B,S,V): scan over S chunks."""
    b, s, d = x.shape
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    nc = x.shape[1] // chunk
    xc = x.reshape(b, nc, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, nc, chunk).transpose(1, 0, 2)

    def step(tot, inp):
        xx, ll = inp
        logits = jnp.einsum("bsd,dv->bsv", xx, head).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(ll, 0)[..., None], axis=-1)[..., 0]
        valid = ll >= 0
        nll = jnp.where(valid, lse - gold, 0.0)
        return (tot[0] + nll.sum(), tot[1] + valid.sum()), None

    (tot, cnt), _ = lax.scan(
        step, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32)),
        (xc, lc))
    return tot / jnp.maximum(cnt, 1)


def loss_fn(cfg: ArchConfig, params: Params, batch: Dict[str, jax.Array],
            opts: ModelOptions = DEFAULT_OPTIONS) -> jax.Array:
    x, positions = embed_inputs(cfg, params, batch, opts)
    x, aux = backbone(cfg, params, x, positions, opts)
    x = L.rmsnorm(x, params["final_norm"])
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    labels = batch["labels"]
    if labels.shape[1] != x.shape[1]:       # stub modality prefix: no loss
        pad = x.shape[1] - labels.shape[1]
        labels = jnp.pad(labels, ((0, 0), (pad, 0)), constant_values=-1)
    ce = _chunked_ce(x, head, labels)
    return ce + 0.01 * aux


# --------------------------------------------------------------------------
# decode (serve_step)
# --------------------------------------------------------------------------

def _kv_cache_len(cfg: ArchConfig, max_seq: int) -> int:
    if cfg.sliding_window is not None:
        return min(cfg.sliding_window, max_seq)
    return max_seq


def init_cache(cfg: ArchConfig, batch: int, max_seq: int,
               opts: ModelOptions = DEFAULT_OPTIONS) -> Dict[str, Any]:
    """Decode cache pytree (all-zeros; kpos 2**30 marks empty)."""
    cache: Dict[str, Any] = {"pos": jnp.zeros((batch,), jnp.int32)}
    s = _kv_cache_len(cfg, max_seq)
    hd, kh = cfg.head_dim, cfg.n_kv_heads

    def kv(n):
        return {
            "k": jnp.zeros((n, batch, s, kh, hd), opts.dtype),
            "v": jnp.zeros((n, batch, s, kh, hd), opts.dtype),
            "kpos": jnp.full((n, batch, s), 2 ** 30, jnp.int32),
        }

    if cfg.family == "ssm":
        cache["ssm"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape).copy(),
            S.init_ssm_cache(batch, cfg.d_model, cfg.ssm, opts.dtype))
    elif cfg.hybrid_period:
        n_per = cfg.n_layers // cfg.hybrid_period
        cache["attn"] = kv(n_per)
        n_moe, n_dense = hybrid_ssm_split(cfg)
        base = S.init_ssm_cache(batch, cfg.d_model, cfg.ssm, opts.dtype)
        for kind, n in (("ssm_moe", n_moe), ("ssm_dense", n_dense)):
            if n:
                cache[kind] = jax.tree.map(
                    lambda x, n=n: jnp.broadcast_to(
                        x, (n_per, n) + x.shape).copy(), base)
    else:
        cache["attn"] = kv(cfg.n_layers)
    return cache


def _attn_decode_block(cfg, p, x, pos, kcache, opts):
    """x: (B,1,d); kcache: dict(k,v,kpos) for THIS layer (B,S,KH,hd)."""
    b = x.shape[0]
    h = L.rmsnorm(x, p["ln"])
    q = jnp.einsum("bsd,de->bse", h, p["wq"])
    k = jnp.einsum("bsd,de->bse", h, p["wk"])
    v = jnp.einsum("bsd,de->bse", h, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    hd = cfg.head_dim
    q = q.reshape(b, 1, cfg.n_heads, hd)
    k = k.reshape(b, 1, cfg.n_kv_heads, hd)
    v = v.reshape(b, 1, cfg.n_kv_heads, hd)
    qpos = pos[:, None]                                   # (B,1)
    q = L.apply_rope(q, qpos, cfg.rope_theta)
    k = L.apply_rope(k, qpos, cfg.rope_theta)

    s = kcache["k"].shape[1]
    slot = (pos % s).astype(jnp.int32)                    # ring-buffer write
    bi = jnp.arange(b)
    knew = kcache["k"].at[bi, slot].set(k[:, 0])
    vnew = kcache["v"].at[bi, slot].set(v[:, 0])
    kposn = kcache["kpos"].at[bi, slot].set(pos)

    o = L.attention_decode(q, knew, vnew, qpos, kposn,
                           window=cfg.sliding_window)
    o = jnp.einsum("bse,ed->bsd", o.reshape(b, 1, cfg.n_heads * hd), p["wo"])
    return x + o, {"k": knew, "v": vnew, "kpos": kposn}


def _ssm_decode_layer(cfg, p, x, cache, opts):
    h = L.rmsnorm(x, p["ln"])
    sp = {k: v for k, v in p.items() if k not in ("ln", "ffn")}
    y, new_cache = S.ssm_block_decode(h, sp, cfg.ssm, cache)
    x = x + y
    if "ffn" in p:
        x, _ = _ffn_block(cfg, p["ffn"], x, opts)
    return x, new_cache


def decode_step(cfg: ArchConfig, params: Params, cache: Dict[str, Any],
                batch: Dict[str, jax.Array],
                opts: ModelOptions = DEFAULT_OPTIONS):
    """One-token decode. batch: {tokens: (B,1)}. Returns (logits(B,V), cache)."""
    tok = batch["tokens"]
    x = params["embed"][tok].astype(opts.dtype)           # (B,1,d)
    pos = cache["pos"]

    if cfg.family == "ssm":
        def body(h, xs):
            lp, lc = xs
            hh, nc = _ssm_decode_layer(cfg, lp, h, lc, opts)
            return hh, nc
        x, new_ssm = lax.scan(body, x, (params["ssm_layers"], cache["ssm"]))
        new_cache = {**cache, "ssm": new_ssm, "pos": pos + 1}

    elif cfg.hybrid_period:
        ssm_kinds = [k for k in ("ssm_moe", "ssm_dense")
                     if f"{k}_layers" in params]

        def period(h, xs):
            ap = xs["attn_p"]
            pa = {k: v for k, v in ap.items() if k != "ffn"}
            h, nac = _attn_decode_block(cfg, pa, h, pos, xs["attn_c"], opts)
            h, _ = _ffn_block(cfg, ap["ffn"], h, opts)

            def inner(hh, ys):
                sp, sc = ys
                hh, nsc = _ssm_decode_layer(cfg, sp, hh, sc, opts)
                return hh, nsc

            new_sc = {}
            for kind in ssm_kinds:
                h, new_sc[kind] = lax.scan(
                    inner, h, (xs[f"{kind}_p"], xs[f"{kind}_c"]))
            return h, (nac, new_sc)

        xs = {"attn_p": params["attn_layers"], "attn_c": cache["attn"]}
        for kind in ssm_kinds:
            xs[f"{kind}_p"] = params[f"{kind}_layers"]
            xs[f"{kind}_c"] = cache[kind]
        x, (new_attn, new_ssm) = lax.scan(period, x, xs)
        new_cache = {**cache, "attn": new_attn, "pos": pos + 1}
        for kind in ssm_kinds:
            new_cache[kind] = new_ssm[kind]

    else:
        def body(h, xs):
            lp, lc = xs
            pa = {k: v for k, v in lp.items() if k != "ffn"}
            h, nc = _attn_decode_block(cfg, pa, h, pos, lc, opts)
            h, _ = _ffn_block(cfg, lp["ffn"], h, opts)
            return h, nc
        x, new_attn = lax.scan(body, x, (params["attn_layers"],
                                         cache["attn"]))
        new_cache = {**cache, "attn": new_attn, "pos": pos + 1}

    x = L.rmsnorm(x, params["final_norm"])
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head)[:, 0]
    return logits, new_cache
