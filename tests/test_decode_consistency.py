"""Decode-vs-forward consistency: running the decode path token-by-token
must reproduce the teacher-forced forward logits — validates KV caches,
SSM recurrent states, ring buffers and rope positions across families.

MoE root cause (was a "seed-known defect", now understood): capacity-
factor routing is non-causal along the sequence — the per-expert argsort
competes ALL tokens, including future positions, for cap slots, so a
token's drop fate depends on tokens after it. Token-by-token decode sees
a different competitor set by construction and CANNOT reproduce a
batched forward that dropped tokens. Where consistency is well-defined
(dropless capacity: no competition binds) decode matches exactly; the
minimal repro below pins the divergence to exactly the drop mechanism.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, smoke_config
from repro.models import moe as M
from repro.models.api import build_model
from repro.models.layers import ModelOptions

OPTS = ModelOptions(dtype=jnp.float32, remat=False, attn_impl="naive")

# one representative per family (full 10-arch coverage in smoke tests).
# MoE archs are tested at dropless capacity — the only regime where
# decode == forward is mathematically possible (module docstring).
FAMILIES = ["qwen2_1_5b",            # dense GQA
            "h2o_danube_1_8b",       # SWA
            "mamba2_2_7b",           # SSM
            "qwen3_moe_30b_a3b",     # MoE
            "jamba_v0_1_52b",        # hybrid
            "whisper_tiny"]          # enc-dec


def _dropless(cfg):
    if cfg.moe is None:
        return cfg
    return dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, capacity_factor=M.dropless_capacity_factor(cfg.moe)))


@pytest.mark.parametrize("arch", FAMILIES)
def test_decode_matches_forward(arch):
    cfg = _dropless(smoke_config(get_config(arch)))
    api = build_model(cfg, OPTS)
    key = jax.random.PRNGKey(1)
    params = api.init(key)
    b, s = 2, 16
    toks = jax.random.randint(jax.random.fold_in(key, 1), (b, s), 1,
                              cfg.vocab, jnp.int32)

    if cfg.enc_dec:
        frames = jax.random.normal(jax.random.fold_in(key, 2),
                                   (b, 8, cfg.d_model), jnp.float32)
        batch = {"tokens": toks, "frame_embeds": frames}
        full = api.forward(params, batch)           # (b, s, V)
        from repro.models import encdec
        enc_out = encdec.encode(cfg, params, frames, OPTS)
        ck, cv = encdec.precompute_cross(cfg, params, enc_out)
        cache = {**api.init_cache(b, s), "cross_k": ck, "cross_v": cv}
    else:
        batch = {"tokens": toks}
        full = api.forward(params, batch)
        cache = api.init_cache(b, s)

    step = jax.jit(api.decode_step)
    for t in range(s):
        logits, cache = step(params, cache, {"tokens": toks[:, t:t + 1]})
        ref = full[:, t]
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(ref), atol=2e-3, rtol=2e-3,
            err_msg=f"{arch}: mismatch at position {t}")


def test_moe_capacity_drops_are_non_causal():
    """Minimal repro of the (formerly unexplained) MoE decode defect.

    1. at the default capacity factor, the smoke config's batched
       forward DOES drop tokens (an expert oversubscribes), and decode
       diverges from forward past the first dropped position;
    2. raising ONLY the capacity factor to the dropless point makes
       decode match forward exactly — pinning the divergence to the
       drop mechanism, not the KV/SSM caches.
    """
    cfg = smoke_config(get_config("qwen3_moe_30b_a3b"))
    b, s = 2, 16
    t = b * s
    cap = M.capacity(t, cfg.moe)
    assert cap < t                    # capacity CAN bind for this config

    api = build_model(cfg, OPTS)
    key = jax.random.PRNGKey(1)
    params = api.init(key)
    toks = jax.random.randint(jax.random.fold_in(key, 1), (b, s), 1,
                              cfg.vocab, jnp.int32)
    full = api.forward(params, {"tokens": toks})
    cache = api.init_cache(b, s)
    step = jax.jit(api.decode_step)
    errs = []
    for pos in range(s):
        logits, cache = step(params, cache, {"tokens": toks[:, pos:pos + 1]})
        errs.append(float(jnp.abs(logits - full[:, pos]).max()))
    assert max(errs) > 1e-3           # drops happened -> decode diverges
    assert errs[0] < 1e-5             # ...but not at position 0

    # same weights, dropless capacity: exact agreement
    dcfg = _dropless(cfg)
    assert M.capacity(t, dcfg.moe) == t
    dapi = build_model(dcfg, OPTS)
    dfull = dapi.forward(params, {"tokens": toks})
    dcache = dapi.init_cache(b, s)
    dstep = jax.jit(dapi.decode_step)
    for pos in range(s):
        logits, dcache = dstep(params, dcache,
                               {"tokens": toks[:, pos:pos + 1]})
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(dfull[:, pos]),
                                   atol=2e-3, rtol=2e-3)


def test_swa_ring_buffer_evicts_correctly():
    """With window w, decode at position >= w must match forward —
    exercising slot eviction in the rolling cache."""
    cfg = smoke_config(get_config("h2o_danube_1_8b"))
    assert cfg.sliding_window == 32
    api = build_model(cfg, OPTS)
    key = jax.random.PRNGKey(3)
    params = api.init(key)
    b, s = 1, 48                      # > window 32
    toks = jax.random.randint(key, (b, s), 1, cfg.vocab, jnp.int32)
    full = api.forward(params, {"tokens": toks})
    cache = api.init_cache(b, s)
    step = jax.jit(api.decode_step)
    for t in range(s):
        logits, cache = step(params, cache, {"tokens": toks[:, t:t + 1]})
    np.testing.assert_allclose(np.asarray(logits),
                               np.asarray(full[:, -1]), atol=2e-3,
                               rtol=2e-3)
