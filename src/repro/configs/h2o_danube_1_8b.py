"""h2o-danube-1.8b [dense] — llama+mistral mix with sliding-window attention.

24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000
[arXiv:2401.16818; hf]

long_500k INCLUDED: SWA gives a bounded (4k) rolling KV cache, i.e.
sub-quadratic long-context decode (DESIGN.md §4).
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="h2o_danube_1_8b",
    family="dense",
    n_layers=24,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    d_ff=6912,
    vocab=32000,
    sliding_window=4096,
    rope_theta=1e4,
    shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
    source="arXiv:2401.16818; hf",
))
