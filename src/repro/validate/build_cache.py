"""Content-addressed build cache for the accuracy sweep (tentpole of
the sweep-scale subsystem).

The paper's unique-event dedup (Observation 1) makes *profiling* cheap,
but the sweep was still rebuilding the per-cell model graph
(``build_positions``) and the engine's event-mean precomputation for
every cell — the dominant cost of small validation cells. Those builds
are pure functions of ``(arch, smoke, strategy, microbatch, seq,
cluster)``, and large parts of the key collapse further:

* **positions** depend only on (arch, smoke, mp, pp·vpp, microbatch,
  seq, cluster) — not on dp, schedule or the microbatch *count*;
* the **engine build** (:class:`repro.core.engine.EngineBuild` — event
  means, p2p/DP-sync/optimizer means) additionally depends on dp /
  zero1 / grad_compress but still NOT on the pipeline schedule or
  microbatch count: a schedule only reorders tasks over the same
  stage/event structure (verified bit-identical in
  ``tests/test_sweep_scale.py``), so the full matrix — where each
  (model, strategy) pair recurs across 4 schedules — shares one build
  across the same-vpp schedules of each pair (gpipe/1f1b/pipedream;
  interleaved's vpp=2 builds its own position structure);
* the **engine** itself (schedule task lists over a build) is cached on
  the full key, so re-sweeping with a warm cache skips everything.

Cached sweeps are bit-identical to uncached ones: every number the
engine consumes is the same profiled float either way. The cache is
bound to one provider and self-invalidates when that provider's event
cache is cleared (``Provider.cache_version``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.configs.base import ArchConfig, get_config, smoke_config
from repro.core.engine import EngineBuild, EventFlowEngine
from repro.core.events import Stage, Strategy
from repro.core.hierarchy import build_positions
from repro.core.profiler import Provider
from repro.core.scenario import TRAIN, Scenario


@dataclasses.dataclass
class BuildCacheStats:
    """Hit/miss accounting per cache level (reported by
    ``benchmarks/bench_validate.py``)."""
    positions_hits: int = 0
    positions_misses: int = 0
    build_hits: int = 0
    build_misses: int = 0
    engine_hits: int = 0
    engine_misses: int = 0
    invalidations: int = 0

    @property
    def hits(self) -> int:
        return self.positions_hits + self.build_hits + self.engine_hits

    @property
    def misses(self) -> int:
        return (self.positions_misses + self.build_misses
                + self.engine_misses)

    def to_dict(self) -> Dict[str, int]:
        return dataclasses.asdict(self)

    def merge(self, other: "BuildCacheStats") -> None:
        """Accumulate a worker shard's accounting (parallel executor)."""
        for f in dataclasses.fields(self):
            setattr(self, f.name,
                    getattr(self, f.name) + getattr(other, f.name))


def _strip_schedule(strat: Strategy) -> Strategy:
    """The strategy modulo schedule + microbatch count — the part an
    :class:`EngineBuild` actually depends on."""
    return dataclasses.replace(strat, schedule="", microbatches=1)


class BuildCache:
    """Per-provider cache of positions / engine builds / engines.

    All keys are content-addressed (arch name + smoke flag + frozen
    ``Strategy`` + derived microbatch + seq); the cluster is implied by
    the bound provider. Use one cache per sweep (or per worker shard —
    see :mod:`repro.validate.executor`).
    """

    def __init__(self, provider: Provider):
        self.provider = provider
        self._positions: Dict[Tuple, List[Stage]] = {}
        self._builds: Dict[Tuple, EngineBuild] = {}
        self._engines: Dict[Tuple, EventFlowEngine] = {}
        self._version = provider.cache_version
        self.stats = BuildCacheStats()

    # ------------------------------------------------------------------

    def _check_version(self) -> None:
        """Everything cached here bakes in provider event means — a
        provider cache clear invalidates all three levels at once."""
        if self._version != self.provider.cache_version:
            self._positions.clear()
            self._builds.clear()
            self._engines.clear()
            self._version = self.provider.cache_version
            self.stats.invalidations += 1

    @staticmethod
    def _microbatch(strat: Strategy, global_batch: int,
                    scenario: Scenario = TRAIN) -> int:
        # delegate to the ONE shared derivation (Scenario → Strategy)
        # so this cache key can never drift from DistSim.microbatch()
        return scenario.microbatch_size(strat, global_batch)

    @staticmethod
    def _resolve(arch: str, smoke: bool) -> ArchConfig:
        cfg = get_config(arch)
        return smoke_config(cfg) if smoke else cfg

    # ---- cfg-object-keyed surface (search engine / mega-batch) ----
    # ArchConfig is a frozen dataclass, so the config VALUE is the key:
    # callers that already hold a config (SearchEngine) skip the
    # registry entirely, and two arch names that resolve to an equal
    # config collapse to one entry.

    def positions_for(self, cfg: ArchConfig, strat: Strategy,
                      microbatch: int, seq: int,
                      scenario: Scenario = TRAIN) -> List[Stage]:
        self._check_version()
        sc = scenario.stripped()
        key = (cfg, strat.mp, strat.pp, strat.vpp, microbatch, seq, sc)
        hit = self._positions.get(key)
        if hit is not None:
            self.stats.positions_hits += 1
            return hit
        self.stats.positions_misses += 1
        pos = build_positions(cfg, strat, microbatch, seq,
                              self.provider.cluster, scenario=sc)
        self._positions[key] = pos
        return pos

    def build_for(self, cfg: ArchConfig, strat: Strategy,
                  microbatch: int, seq: int,
                  scenario: Scenario = TRAIN) -> EngineBuild:
        self._check_version()
        sc = scenario.stripped()
        key = (cfg, _strip_schedule(strat), microbatch, seq, sc)
        hit = self._builds.get(key)
        if hit is not None:
            self.stats.build_hits += 1
            return hit
        ext = self._build_fallback(key)
        if ext is not None:
            self._builds[key] = ext
            self.stats.build_hits += 1
            return ext
        self.stats.build_misses += 1
        pos = self.positions_for(cfg, strat, microbatch, seq, sc)
        # with_dp_sync=None: precompute sync means whenever dp > 1 so
        # pipedream and the syncing schedules share one build
        build = EngineBuild(pos, strat, self.provider, with_dp_sync=None,
                            scenario=sc)
        self._builds[key] = build
        self._build_created(key, build)
        return build

    # secondary-lookup hooks for subclasses backed by external storage
    # (repro.store.PersistentBuildCache): a fallback hit counts as a
    # build hit, a freshly-computed build is offered for persisting.
    def _build_fallback(self, key: Tuple) -> Optional[EngineBuild]:
        return None

    def _build_created(self, key: Tuple, build: EngineBuild) -> None:
        pass

    def engine_for_cfg(self, cfg: ArchConfig, strat: Strategy,
                       global_batch: int, seq: int,
                       scenario: Scenario = TRAIN) -> EventFlowEngine:
        self._check_version()
        micro = self._microbatch(strat, global_batch, scenario)
        # engines key on the FULL scenario (decode step count/arrivals
        # are schedule-level); builds/positions on the stripped one
        key = (cfg, strat, micro, seq, scenario)
        hit = self._engines.get(key)
        if hit is not None:
            self.stats.engine_hits += 1
            return hit
        self.stats.engine_misses += 1
        build = self.build_for(cfg, strat, micro, seq, scenario)
        eng = EventFlowEngine(build.stages, strat, self.provider,
                              build=build, scenario=scenario)
        self._engines[key] = eng
        return eng

    # ---- registry-name surface (validation sweep cells) ----

    def positions(self, arch: str, smoke: bool, strat: Strategy,
                  microbatch: int, seq: int,
                  scenario: Scenario = TRAIN) -> List[Stage]:
        return self.positions_for(self._resolve(arch, smoke), strat,
                                  microbatch, seq, scenario)

    def build(self, arch: str, smoke: bool, strat: Strategy,
              microbatch: int, seq: int,
              scenario: Scenario = TRAIN) -> EngineBuild:
        return self.build_for(self._resolve(arch, smoke), strat,
                              microbatch, seq, scenario)

    def engine(self, arch: str, smoke: bool, strat: Strategy,
               global_batch: int, seq: int,
               scenario: Scenario = TRAIN) -> EventFlowEngine:
        return self.engine_for_cfg(self._resolve(arch, smoke), strat,
                                   global_batch, seq, scenario)

    def engine_for(self, cell) -> EventFlowEngine:
        """Engine for a :class:`repro.validate.sweep.ValidationCell`."""
        return self.engine(cell.arch, cell.smoke, cell.strategy,
                           cell.global_batch, cell.seq,
                           getattr(cell, "scenario", TRAIN))

    # ------------------------------------------------------------------

    def snapshot(self) -> Dict[str, int]:
        """Accounting summary: per-level hits/misses + entry counts."""
        out = self.stats.to_dict()
        out.update(positions_entries=len(self._positions),
                   build_entries=len(self._builds),
                   engine_entries=len(self._engines))
        return out
