"""Perturbation axis: stragglers/faults as timeline events.

The two load-bearing guarantees:

* ``perturb=None`` — and an empty :class:`Perturbation` — leave every
  predict/replay path BIT-identical to the unperturbed engine
  (differential oracle: compared against ``engine.run()`` /
  ``run_batched()`` outputs, not tolerances);
* store/build/query addresses never key on the perturbation, so every
  pre-perturb serialized artifact stays byte-identical.
"""
import dataclasses
import json
import os

import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core import (A40_CLUSTER, AnalyticalProvider, DistSim, Fault,
                        MegaBatch, Perturbation, Straggler, Strategy,
                        perturbation_from_dict)
from repro.core.perturb import OPEN, restore_manifest
from repro.core.scenario import Decode
from repro.store.profile_store import build_key_json
from repro.store.serve import ServeQuery
from repro.validate import degraded_matrix, run_degraded

GOLDEN = os.path.join(os.path.dirname(__file__), "goldens",
                      "validation_degraded.json")


def _sim(mp=1, pp=2, dp=2, m=4, gb=16, **kw):
    return DistSim(get_config("gpt2_345m"),
                   Strategy(mp=mp, pp=pp, dp=dp, microbatches=m,
                            schedule="1f1b"), gb, 512, **kw)


# ------------------------ spec validation ------------------------

def test_spec_validation():
    with pytest.raises(ValueError):
        Straggler(rank=-1, factor=1.5)
    with pytest.raises(ValueError):
        Straggler(rank=0, factor=0.0)
    with pytest.raises(ValueError):
        Straggler(rank=0, factor=1.5, window=(4, 2))
    with pytest.raises(ValueError):
        Fault(rank=0, at_step=-1)
    with pytest.raises(ValueError):
        Perturbation(steps=0)
    with pytest.raises(ValueError):                 # duplicate fault rank
        Perturbation(faults=(Fault(0, 1), Fault(0, 3)), steps=8)
    with pytest.raises(ValueError):                 # fault outside run
        Perturbation(faults=(Fault(0, 9),), steps=8)
    # faults sorted by at_step regardless of input order
    p = Perturbation(faults=(Fault(1, 5), Fault(0, 2)), steps=8)
    assert [f.at_step for f in p.faults] == [2, 5]
    assert Straggler(0, 2.0, window=(1, 3)).covers(2)
    assert not Straggler(0, 2.0, window=(1, 3)).covers(3)
    assert Straggler(0, 2.0).covers(10 ** 9)        # OPEN window


def test_speed_grid_layout_and_range():
    # rank = (r*pp + d)*mp + j; the whole mp group slows together
    strat = Strategy(mp=2, pp=2, dp=2, microbatches=4)
    p = Perturbation(stragglers=(Straggler(2, 1.5),))    # r=0, d=1
    grid = p.speed_grid(strat)
    assert grid.shape == (2, 2)
    assert grid[0, 1] == 1.5 and grid.sum() == 4.5
    with pytest.raises(ValueError, match="out of range"):
        Perturbation(stragglers=(Straggler(8, 2.0),)).speed_grid(strat)
    # stacked stragglers on one rank multiply
    p2 = Perturbation(stragglers=(Straggler(2, 1.5), Straggler(2, 2.0)))
    assert p2.speed_grid(strat)[0, 1] == 3.0


def test_serde_roundtrip():
    p = Perturbation(
        stragglers=(Straggler(1, 1.5, (2, 6)), Straggler(3, 2.0)),
        faults=(Fault(2, 5, detect_s=0.5),),
        steps=12, save_every=3, replan_s=1.0)
    assert perturbation_from_dict(p.to_dict()) == p
    assert perturbation_from_dict(None) is None
    assert json.loads(json.dumps(p.to_dict())) == p.to_dict()
    assert p.label() == "slow1x1.5@2:6+slow3x2+fault2@5"
    assert Perturbation().label() == "clean"


# ------------------------ bit-identity (differential) ------------------------

def test_zero_perturbation_is_bit_identical():
    eng = _sim().engine()
    empty = Perturbation(steps=1)
    assert np.array_equal(eng.run_batched(None).batch_times,
                          eng.run_batched(None, perturb=empty)
                          .batch_times)
    seeds = [0, 1, 2]
    ref = eng.run_batched(seeds, jitter_sigma=0.025,
                          straggler_sigma=0.01).batch_times
    out = eng.run_batched(seeds, jitter_sigma=0.025,
                          straggler_sigma=0.01,
                          perturb=empty).batch_times
    assert np.array_equal(ref, out)
    assert eng.run(jitter_sigma=0.025, seed=1).batch_time \
        == eng.run(jitter_sigma=0.025, seed=1, perturb=empty).batch_time


def test_perturbed_run_matches_run_batched():
    eng = _sim().engine()
    p = Perturbation(stragglers=(Straggler(1, 1.7),))
    assert eng.run(perturb=p).batch_time \
        == float(eng.run_batched(None, perturb=p).batch_times[0])
    assert eng.run(jitter_sigma=0.025, seed=3, perturb=p).batch_time \
        == float(eng.run_batched([3], jitter_sigma=0.025,
                                 perturb=p).batch_times[0])


def test_straggler_monotone_in_factor():
    eng = _sim().engine()
    base = float(eng.run_batched(None).batch_times[0])
    times = []
    for f in (1.0, 1.25, 1.5, 2.0):
        p = Perturbation(stragglers=(Straggler(1, f), Straggler(3, f)))
        times.append(float(eng.run_batched(None, perturb=p)
                           .batch_times[0]))
    assert times[0] == base                      # exact, not approx
    assert all(a < b for a, b in zip(times, times[1:]))


def test_engine_rejects_faults():
    eng = _sim().engine()
    p = Perturbation(faults=(Fault(0, 1),), steps=4)
    with pytest.raises(ValueError, match="run level"):
        eng.run(perturb=p)
    with pytest.raises(ValueError, match="run level"):
        eng.run_batched(None, perturb=p)


# ------------------------ megabatch ------------------------

def test_megabatch_perturbed_bit_identical_to_engine():
    eng = _sim().engine()
    p = Perturbation(stragglers=(Straggler(1, 1.5), Straggler(3, 1.5)))
    mb = float(MegaBatch([eng], perturb=p).predict("numpy")
               .batch_times[0])
    assert mb == eng.run(perturb=p).batch_time
    # and the unperturbed program is untouched by the feature
    assert float(MegaBatch([eng]).predict("numpy").batch_times[0]) \
        == float(eng.run_batched(None).batch_times[0])


def test_megabatch_rejects_nonuniform_and_faults():
    eng = _sim().engine()
    with pytest.raises(ValueError, match="uniform across DP"):
        MegaBatch([eng], perturb=Perturbation(
            stragglers=(Straggler(1, 1.5),))).predict("numpy")
    with pytest.raises(ValueError, match="run level"):
        MegaBatch([eng], perturb=Perturbation(faults=(Fault(0, 1),),
                                              steps=4))


# ------------------------ fault splice ------------------------

def test_fault_recovery_splice():
    sim = _sim()
    p = Perturbation(faults=(Fault(3, 6, detect_s=0.5),), steps=12,
                     save_every=4, replan_s=1.5)
    run = sim.simulate(perturb=p)
    assert run.steps == 12 and len(run.recoveries) == 1
    rec = run.recoveries[0]
    assert rec.ckpt_step == 4 and rec.lost_steps == 2
    assert rec.survivors == 3
    assert rec.plan.model == 2 and rec.plan.data == 1
    assert run.final_strategy.dp == 1            # mp*pp kept intact
    assert run.final_strategy.mp * run.final_strategy.pp == 2
    assert run.effective_global_batch == 8       # microbatch constant
    kinds = [e.kind for e in rec.events]
    assert kinds == ["detect", "restore", "replan", "recompute"]
    durs = {e.kind: float(e.duration[0]) for e in rec.events}
    assert durs["detect"] == 0.5 and durs["replan"] == 1.5
    assert durs["restore"] > 0
    # exact decomposition: 6 pre-fault + recovery + 6 post-replan steps
    expected = (6 * run.baseline_step_time + rec.recovery_times
                + 6 * run.post_failure_step_time)
    np.testing.assert_allclose(run.total_times, expected, rtol=1e-12)
    # timeline spans are contiguous from 0
    tl = run.timeline(0)
    assert tl[0][1] == 0.0
    assert all(a[2] == b[1] for a, b in zip(tl, tl[1:]))
    assert tl[-1][2] == pytest.approx(float(run.total_times[0]))


def test_post_replan_runs_clean_of_stragglers():
    """Mitigation (b): flagged stragglers are excluded at the re-plan,
    so the post-failure segment matches the clean surviving grid."""
    sim = _sim()
    p = Perturbation(stragglers=(Straggler(1, 3.0),),
                     faults=(Fault(3, 4),), steps=8, save_every=4)
    run = sim.simulate(perturb=p)
    post = [s for s in run.segments if s.start >= 4]
    assert post and all(not s.stragglers for s in post)
    # pre-fault segment IS perturbed (strictly slower than baseline)
    pre = [s for s in run.segments if s.stop <= 4]
    assert any(float(s.step_times[0])
               > float(run.baseline_step_time[0]) for s in pre)


def test_straggler_window_cuts_segments():
    sim = _sim()
    p = Perturbation(stragglers=(Straggler(1, 2.0, window=(2, 6)),),
                     steps=8)
    run = sim.simulate(perturb=p)
    assert [(s.start, s.stop) for s in run.segments] \
        == [(0, 2), (2, 6), (6, 8)]
    t0, t1, t2 = (float(s.step_times[0]) for s in run.segments)
    assert t0 == t2                              # same clean evaluation
    assert t1 > t0
    # open-ended window: straggler active to the end of the run
    run2 = sim.simulate(perturb=Perturbation(
        stragglers=(Straggler(1, 2.0, window=(2, OPEN)),), steps=8))
    assert [(s.start, s.stop) for s in run2.segments] \
        == [(0, 2), (2, 8)]


def test_zero1_shrinks_restore_read():
    sim = _sim()
    stages = sim.engine().stages
    plain = restore_manifest(stages, sim.strategy, 4)
    z1 = restore_manifest(
        stages, dataclasses.replace(sim.strategy, zero1=True), 4)
    from repro.train.checkpoint import manifest_nbytes
    assert manifest_nbytes(z1) < manifest_nbytes(plain)


def test_double_fault_replans_twice():
    sim = _sim(mp=1, pp=1, dp=4, m=2)
    p = Perturbation(faults=(Fault(0, 3), Fault(2, 7)), steps=10,
                     save_every=4)
    run = sim.simulate(perturb=p)
    assert [r.survivors for r in run.recoveries] == [3, 2]
    assert [r.plan.data for r in run.recoveries] == [2, 2]
    assert run.final_strategy.dp == 2
    assert run.effective_global_batch == 8
    assert run.steps_lost == 6                   # 3 + 3 recomputed


def test_unrecoverable_and_invalid_faults_raise():
    sim = _sim(mp=1, pp=2, dp=1, m=4)            # world=2 == mp*pp
    with pytest.raises(ValueError, match="unrecoverable"):
        sim.simulate(perturb=Perturbation(faults=(Fault(0, 1),),
                                          steps=4))
    with pytest.raises(ValueError, match="out of range"):
        _sim().simulate(perturb=Perturbation(faults=(Fault(9, 1),),
                                             steps=4))
    with pytest.raises(ValueError, match="training-run"):
        DistSim(get_config("gpt2_345m"),
                Strategy(mp=1, pp=2, dp=2, microbatches=4), 8, 512,
                scenario=Decode(steps=4)).simulate(
            perturb=Perturbation(faults=(Fault(0, 1),), steps=4))
    with pytest.raises(ValueError, match="scenario"):
        _sim().simulate(perturb=Perturbation(steps=4),
                        scenario=Decode(steps=4))


def test_seeded_degraded_run_has_lanes():
    run = _sim().simulate(perturb=Perturbation(
        stragglers=(Straggler(1, 1.5),),
        faults=(Fault(3, 4),), steps=8, save_every=4), seeds=(0, 1))
    assert run.total_times.shape == (2,)
    assert run.seeds == [0, 1]
    assert float(run.total_times[0]) != float(run.total_times[1])
    d = run.to_dict()
    assert json.loads(json.dumps(d)) == d


# ------------------------ address/serialization stability ------------------------

def test_build_keys_carry_no_perturb_field():
    """Perturbations multiply profiled means at run-evaluation time;
    builds and store addresses must not know they exist."""
    sim = _sim()
    key = (sim.cfg, sim.strategy.stripped()
           if hasattr(sim.strategy, "stripped") else sim.strategy,
           2, 512)
    assert "perturb" not in build_key_json(key)


def test_serve_query_serialization_unchanged_when_clean():
    q = ServeQuery("gpt2_345m", Strategy(mp=1, pp=2, dp=2,
                                         microbatches=4))
    d = q.to_dict()
    assert "perturb" not in d                    # pre-perturb bytes
    assert ServeQuery.from_dict(d) == q
    p = Perturbation(stragglers=(Straggler(1, 1.5), Straggler(3, 1.5)))
    qp = dataclasses.replace(q, perturb=p)
    dp = qp.to_dict()
    assert dp["perturb"] == p.to_dict()
    assert ServeQuery.from_dict(json.loads(json.dumps(dp))) == qp


def test_serve_answers_perturbed_queries(tmp_path):
    server = DistSim.serve(str(tmp_path))
    q = ServeQuery("gpt2_345m", Strategy(mp=1, pp=2, dp=2,
                                         microbatches=4))
    p = Perturbation(stragglers=(Straggler(1, 1.5), Straggler(3, 1.5)))
    clean, slow = server.answer_batch(
        [q, dataclasses.replace(q, perturb=p)])
    assert slow.batch_time > clean.batch_time
    # the clean lane is byte-identical to the engine's predict on the
    # served cluster (DistSim's default cluster differs from serve's)
    sim = _sim(provider=AnalyticalProvider(A40_CLUSTER))
    assert clean.batch_time == float(sim.simulate().batch
                                     .batch_times[0])
    assert slow.batch_time == sim.engine().run(perturb=p).batch_time


# ------------------------ goldens ------------------------

def test_degraded_matrix_matches_goldens():
    with open(GOLDEN) as f:
        golden = json.load(f)
    report = run_degraded(degraded_matrix())
    assert report.passed, [c.violations for c in report.failures]
    current = json.loads(json.dumps(report.to_dict(), sort_keys=True))
    assert current == golden, \
        "degraded matrix drifted; rerun benchmarks/bench_fault.py " \
        "--update-goldens if intentional"
