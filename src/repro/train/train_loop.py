"""End-to-end training loop: model + AdamW + data + checkpoint/restart
+ heartbeat monitoring. Used by examples/ and the integration tests.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.data.pipeline import DataConfig, DataLoader
from repro.models.api import build_model
from repro.models.layers import ModelOptions
from repro.train import checkpoint as ckpt
from repro.train import optimizer as opt
from repro.train.fault_tolerance import HeartbeatMonitor
from repro.train.step import TrainConfig, make_train_step


@dataclasses.dataclass
class LoopConfig:
    steps: int = 100
    seq_len: int = 256
    global_batch: int = 8
    log_every: int = 10
    save_every: int = 0              # 0 = no checkpointing
    ckpt_dir: Optional[str] = None
    seed: int = 0
    resume: bool = True


@dataclasses.dataclass
class FitResult:
    losses: List[float]
    steps_done: int
    resumed_from: Optional[int]
    step_times: List[float]


def fit(cfg: ArchConfig, opts: ModelOptions = None,
        tcfg: TrainConfig = None, loop: LoopConfig = LoopConfig(),
        verbose: bool = True) -> FitResult:
    opts = opts or ModelOptions(dtype=jnp.float32, remat=False)
    tcfg = tcfg or TrainConfig(adamw=opt.AdamWConfig(
        lr=1e-3, warmup_steps=max(10, loop.steps // 20),
        total_steps=loop.steps))
    api = build_model(cfg, opts)
    key = jax.random.PRNGKey(loop.seed)
    params = api.init(key)
    state = opt.init(params)

    resumed_from = None
    start_step = 0
    if loop.ckpt_dir and loop.resume and ckpt.latest_step(loop.ckpt_dir) \
            is not None:
        (params, state), start_step = ckpt.restore(
            loop.ckpt_dir, (params, state))
        resumed_from = start_step

    step_fn = jax.jit(make_train_step(cfg, opts, tcfg))
    dcfg = DataConfig(seed=loop.seed, vocab=cfg.vocab,
                      seq_len=loop.seq_len, global_batch=loop.global_batch)
    loader = DataLoader(dcfg, start_step=start_step, arch=cfg)
    monitor = HeartbeatMonitor(n_workers=1)

    losses: List[float] = []
    times: List[float] = []
    try:
        for step, batch in loader:
            if step >= loop.steps:
                break
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            t0 = time.perf_counter()
            params, state, metrics = step_fn(params, state, batch)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            monitor.heartbeat(0, dt)
            losses.append(loss)
            times.append(dt)
            if verbose and (step % loop.log_every == 0
                            or step == loop.steps - 1):
                print(f"step {step:5d} loss {loss:8.4f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"gnorm {float(metrics['grad_norm']):8.3f} "
                      f"{dt*1e3:7.1f} ms")
            if loop.save_every and loop.ckpt_dir \
                    and (step + 1) % loop.save_every == 0:
                ckpt.save(loop.ckpt_dir, step + 1, (params, state))
    finally:
        loader.close()
    return FitResult(losses=losses, steps_done=len(losses) + start_step,
                     resumed_from=resumed_from, step_times=times)
