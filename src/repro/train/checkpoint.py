"""Sharded checkpoint/restore with manifest + atomic commit.

Layout (one directory per step):

    <dir>/step_000042/
        manifest.json      # step, leaf paths/shapes/dtypes, status
        arr_<i>.npy        # one file per leaf (host-local shard on a real
                           # cluster; full array on single-host)

Fault-tolerance properties:
  * atomic: written to ``step_X.tmp`` then renamed — a crash mid-write
    never corrupts the latest complete checkpoint;
  * self-describing: restore validates shapes/dtypes against the target
    pytree and fails loudly on config drift;
  * bounded: ``keep`` newest checkpoints retained;
  * resumable: ``latest_step`` scans the directory, so a restarted job
    (elastic rescheduling, preemption) continues from the last commit.

On a multi-host cluster each host writes only the shards it owns
(``jax.experimental.multihost_utils``); this container is single-host,
where process_index()==0 owns everything — same code path.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Optional, Tuple

import jax
import numpy as np


def _leaf_paths(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in leaves]
    return names, [l for _, l in leaves], treedef


def save(directory: str, step: int, tree: Any, keep: int = 3) -> str:
    """Write checkpoint atomically; returns the final path."""
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    names, leaves, _ = _leaf_paths(tree)
    manifest = {"step": step, "leaves": []}
    for i, (name, leaf) in enumerate(zip(names, leaves)):
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(tmp, f"arr_{i}.npy"), arr)
        manifest["leaves"].append(
            {"i": i, "path": name, "shape": list(arr.shape),
             "dtype": str(arr.dtype)})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                      # atomic commit

    # retention
    steps = sorted(all_steps(directory))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"),
                      ignore_errors=True)
    return final


def all_steps(directory: str):
    if not os.path.isdir(directory):
        return []
    out = []
    for d in os.listdir(directory):
        if d.startswith("step_") and not d.endswith(".tmp"):
            try:
                out.append(int(d[5:]))
            except ValueError:
                pass
    return sorted(out)


def latest_step(directory: str) -> Optional[int]:
    steps = all_steps(directory)
    return steps[-1] if steps else None


def restore(directory: str, tree: Any, step: Optional[int] = None
            ) -> Tuple[Any, int]:
    """Restore into the structure of ``tree`` (shape/dtype validated)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)

    names, leaves, treedef = _leaf_paths(tree)
    by_path = {e["path"]: e for e in manifest["leaves"]}
    out = []
    for name, leaf in zip(names, leaves):
        e = by_path.get(name)
        if e is None:
            raise KeyError(f"checkpoint missing leaf {name!r}")
        arr = np.load(os.path.join(path, f"arr_{e['i']}.npy"))
        want = tuple(leaf.shape)
        if tuple(arr.shape) != want:
            raise ValueError(
                f"shape mismatch for {name}: ckpt {arr.shape} vs {want}")
        out.append(arr.astype(leaf.dtype)
                   if hasattr(leaf, "dtype") else arr)
    return jax.tree_util.tree_unflatten(treedef, out), step
