"""Roofline terms from the compiled dry-run artifact (deliverable g).

    compute term    = HLO_FLOPs_per_device / peak_FLOP/s
    memory term     = HLO_bytes_per_device / HBM_bw
    collective term = collective_bytes_per_device / link_bw

``cost_analysis()`` on the SPMD-partitioned module is per-device;
collective bytes are NOT in cost_analysis, so we parse the HLO text and
sum operand sizes of all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute ops. Inter-pod ops (replica groups
crossing the `pod` axis) are charged at DCN bandwidth.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

from repro.core.hw import ChipSpec, V5E

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute")

_SHAPE_RE = re.compile(
    r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


_INSTR_RE = re.compile(
    r"=\s*(?:\(?)((?:" + "|".join(_DTYPE_BYTES) + r")\[[0-9,]*\])"
    r"[^=]*?\b(" + "|".join(_COLL_OPS) + r")(?:-start)?\(")
_GROUP_ITOA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_GROUP_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")


def _group_size(line: str) -> int:
    m = _GROUP_ITOA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUP_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 2


def _line_traffic(s: str):
    """(op, per-device ring traffic bytes) for one instruction line."""
    if re.search(r"\b(?:" + "|".join(_COLL_OPS) + r")-done", s):
        return None
    m = _INSTR_RE.search(s)
    if not m:
        return None
    shape_str, op = m.group(1), m.group(2)
    sm = _SHAPE_RE.search(shape_str)
    if not sm:
        return None
    r = _shape_bytes(sm.group(1), sm.group(2))
    n = _group_size(s)
    if n <= 1:
        return None
    if op == "all-reduce":
        traffic = 2.0 * r * (n - 1) / n
    elif op == "all-gather":
        traffic = r * (n - 1) / n
    elif op == "reduce-scatter":
        traffic = r * (n - 1)
    elif op == "all-to-all":
        traffic = r * (n - 1) / n
    else:                                     # collective-permute
        traffic = r
    return op, traffic


_COMP_HEAD_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*->")
_RESULT_RE = re.compile(r"^(?:ROOT )?%([\w.\-]+) = \(?(\w+)\[([0-9,]*)\]")
_OPCODE_RE = re.compile(r"=\s*[^=]*?\s([a-z][\w\-]*)\(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_LHS_CDIM_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

# opcodes whose "execution" moves no HBM bytes (layout/control plumbing)
_FREE_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
             "bitcast", "while", "conditional", "after-all",
             "add-dependency", "iota", "partition-id", "replica-id"}

# standalone elementwise ops: the CPU backend leaves these unfused, but
# TPU XLA fuses elementwise chains into neighbors — charging each one
# separately would overstate the TPU memory term ~5-10x. They are
# charged ZERO; `fusion` call sites (already-fused groups) carry the
# traffic.
_EW_OPS = {"add", "subtract", "multiply", "divide", "select", "convert",
           "exponential", "exponential-minus-one", "tanh", "maximum",
           "minimum", "negate", "compare", "and", "or", "not", "xor",
           "rsqrt", "sqrt", "log", "log-plus-one", "power", "abs",
           "floor", "ceil", "clamp", "sign", "cosine", "sine",
           "is-finite", "round-nearest-afz", "broadcast", "reshape",
           "transpose", "reduce", "reduce-window", "map",
           "bitcast-convert", "real", "imag", "rem", "shift-left",
           "shift-right-logical", "shift-right-arithmetic", "pad",
           "concatenate", "reverse"}
_CALL_RE = re.compile(
    r"(?:condition|body|to_apply|calls)=%?([\w.\-]+)")
_WHILE_RE = re.compile(
    r"\bwhile\(.*?\),.*?(?:condition=%?([\w.\-]+)).*?(?:body=%?([\w.\-]+))"
    r"|\bwhile\(.*?\),.*?(?:body=%?([\w.\-]+)).*?(?:condition=%?([\w.\-]+))")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _split_computations(hlo_text: str):
    comps = {}
    entry = None
    cur = None
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        m = _COMP_HEAD_RE.match(line.strip())
        if m and line.endswith("{"):
            cur = m.group(2)
            comps[cur] = []
            if m.group(1):
                entry = cur
        elif line.startswith("}"):
            cur = None
        elif cur is not None:
            comps[cur].append(line.strip())
    if entry is None and comps:
        entry = list(comps)[-1]
    return comps, entry


def hlo_stats(hlo_text: str) -> Dict[str, float]:
    """Trip-count-aware HLO statistics: FLOPs (dot ops), HBM bytes
    (operands+results of non-free instructions), and per-device
    collective ring traffic. XLA's own cost_analysis counts while-loop
    bodies ONCE -- useless for scan-over-layers programs -- so this
    analyzer multiplies loop bodies by their trip count (parsed from the
    largest constant in the loop condition).

    Collective traffic per device follows the ring model documented in
    ``_line_traffic``.
    """
    comps, entry = _split_computations(hlo_text)

    shapes = {}
    internal = {}          # comp → names defined by real ops (not
    #                        parameter/gte/constant = loop-external data)
    for cname, lines in comps.items():
        internal[cname] = set()
        for l in lines:
            m = _RESULT_RE.match(l)
            if m:
                shapes[m.group(1)] = (m.group(2), m.group(3))
                om = _OPCODE_RE.search(l)
                if om and om.group(1) not in ("parameter",
                                              "get-tuple-element",
                                              "constant"):
                    internal[cname].add(m.group(1))

    def nbytes_of(name):
        sh = shapes.get(name)
        if sh is None or sh[0] not in _DTYPE_BYTES:
            return 0.0
        return _shape_bytes(sh[0], sh[1])

    def dims_of(name):
        sh = shapes.get(name)
        if sh is None:
            return None
        return [int(d) for d in sh[1].split(",") if d]

    def trip_count(cond_name):
        consts = [int(c) for l in comps.get(cond_name, ())
                  for c in _CONST_RE.findall(l)]
        return max(consts) if consts else 1

    memo = {}
    # VMEM residency: inside a hot loop body (lax.scan over layers /
    # flash blocks / CE chunks), intermediates PRODUCED AND CONSUMED in
    # the same iteration stay on-chip on TPU (fusion + VMEM-resident dot
    # operands), so tensors up to the 128 MiB VMEM defined by in-body
    # ops are not HBM traffic. Loop-carried state (parameters/gte) and
    # larger tensors still pay. This makes the memory term a
    # fused-execution estimate rather than an unfused upper bound.
    VMEM_RESIDENT = 128 * 2 ** 20

    def analyze_comp(name, stack=(), in_loop=False):
        key = (name, in_loop)
        if key in memo:
            return memo[key]
        if name in stack or name not in comps:
            return {}
        own = internal.get(name, set())
        acc = {"flops": 0.0, "bytes": 0.0}
        for line in comps[name]:
            rm = _RESULT_RE.match(line)
            om = _OPCODE_RE.search(line)
            opcode = om.group(1) if om else ""
            # --- collectives ---
            t = _line_traffic(line)
            if t:
                op, traffic = t
                # CPU-backend artifact corrections (TPU is the target):
                # 1. bf16 collectives are promoted/converted to f32 on
                #    CPU (f32 reduction, f32 dot operands); TPU moves
                #    bf16 on the wire → halve.
                if "promoted" in line:
                    traffic *= 0.5
                elif " f32[" in line[:64] or "= f32[" in line[:64]:
                    idx0 = line.find(op + "(")
                    inner0 = (line[idx0 + len(op) + 1:].split(")")[0]
                              if idx0 >= 0 else "")
                    if "convert" in inner0:
                        traffic *= 0.5
                # 2. CPU decomposes reduce-scatter into all-reduce +
                #    dynamic-slice; if this AR's uses are slices (or
                #    fusions that slice it), TPU emits a reduce-scatter
                #    → halve.
                if op == "all-reduce" and rm:
                    iname = rm.group(1)

                    def _slices(u):
                        if "dynamic-slice" in u or "slice" in u:
                            return True
                        if "fusion(" in u:
                            for cal in _CALL_RE.findall(u):
                                if any("dynamic-slice" in bl
                                       for bl in comps.get(cal, ())):
                                    return True
                        return False

                    uses = [u for u in comps[name]
                            if f"%{iname}" in u
                            and not u.startswith(f"%{iname} ")
                            and not u.startswith(f"ROOT %{iname} ")]
                    if uses and all(_slices(u) for u in uses):
                        traffic *= 0.5
                acc[op] = acc.get(op, 0.0) + traffic
                acc["count"] = acc.get("count", 0) + 1
                # HBM side of the collective = corrected wire bytes
                acc["bytes"] += traffic
                continue
            # --- flops: dot ---
            if opcode == "dot" and rm and rm.group(2) in _DTYPE_BYTES:
                res_elems = (_shape_bytes(rm.group(2), rm.group(3))
                             / _DTYPE_BYTES[rm.group(2)])
                k = 1
                cd = _LHS_CDIM_RE.search(line)
                idx = line.find("dot(")
                ops = _OPERAND_RE.findall(
                    line[idx + 4:].split(")")[0]) if idx >= 0 else []
                if ops and cd:
                    lhs_dims = dims_of(ops[0])
                    if lhs_dims:
                        for di in cd.group(1).split(","):
                            if di:
                                k *= lhs_dims[int(di)]
                acc["flops"] += 2.0 * res_elems * k
            # --- bytes ---
            if rm and opcode and opcode not in _FREE_OPS \
                    and opcode not in _EW_OPS:
                res_b = (_shape_bytes(rm.group(2), rm.group(3))
                         if rm.group(2) in _DTYPE_BYTES else 0.0)
                idx = line.find(opcode + "(")
                op_names = []
                if idx >= 0:
                    inner = line[idx + len(opcode) + 1:].split(")")[0]
                    op_names = _OPERAND_RE.findall(inner)
                if in_loop:
                    # VMEM residency: in-body intermediates ≤ threshold
                    # never reach HBM on TPU
                    op_bytes = [0.0 if (n in own
                                        and nbytes_of(n) <= VMEM_RESIDENT)
                                else nbytes_of(n) for n in op_names]
                    if (res_b <= VMEM_RESIDENT
                            and not line.startswith("ROOT")):
                        res_b = 0.0
                else:
                    op_bytes = [nbytes_of(n) for n in op_names]
                iname = rm.group(1)
                # in-place slice updates alias the big operand: charge
                # only the update slice (matches XLA cost semantics)
                if (opcode in ("dynamic-update-slice", "scatter")
                        or "dynamic-update-slice" in iname
                        or "scatter" in iname):
                    rest = sorted(op_bytes)[:-1] if op_bytes else []
                    b = 2.0 * sum(rest)
                # slicing reads only the slice, not the whole operand
                elif (opcode in ("dynamic-slice", "slice", "gather")
                      or "dynamic-slice" in iname
                      or "gather_fusion" in iname):
                    b = 2.0 * res_b
                else:
                    if opcode == "fusion":
                        # scan residuals: a fusion that dynamic-slices a
                        # big stacked operand reads only the slice
                        callees = _CALL_RE.findall(line)
                        body = comps.get(callees[0], []) if callees else []
                        if any("dynamic-slice" in bl for bl in body):
                            op_bytes = [min(ob, max(res_b, 1.0))
                                        for ob in op_bytes]
                    b = res_b + sum(op_bytes)
                # CPU-backend artifact: bf16 dot operands are converted
                # to f32 (and layout-copied in f32) on CPU; the TPU MXU
                # consumes bf16 directly → charge such f32 plumbing at
                # bf16 width. Detected by convert-fusions / copies with
                # f32 results feeding dot_generals.
                if (rm.group(2) == "f32"
                        and (("convert" in rm.group(1))
                             or (opcode == "copy"
                                 and "dot_general" in line))):
                    b *= 0.5
                acc["bytes"] += b
            # --- descend ---
            wm = _WHILE_RE.search(line)
            if wm:
                cond = wm.group(1) or wm.group(4)
                body = wm.group(2) or wm.group(3)
                n = trip_count(cond) if cond else 1
                sub = analyze_comp(body, stack + (name,),
                                   in_loop=(n > 4) or in_loop)
                for kk, v in sub.items():
                    acc[kk] = acc.get(kk, 0.0) + n * v
            elif opcode == "fusion":
                # fused body: count dot FLOPs inside; bytes are already
                # charged at the call site
                for callee in _CALL_RE.findall(line):
                    sub = analyze_comp(callee, stack + (name,), in_loop)
                    acc["flops"] += sub.get("flops", 0.0)
            elif opcode in ("call", "custom-call", "conditional"):
                for callee in _CALL_RE.findall(line):
                    sub = analyze_comp(callee, stack + (name,), in_loop)
                    for kk, v in sub.items():
                        acc[kk] = acc.get(kk, 0.0) + v
        memo[key] = acc
        return acc

    acc = analyze_comp(entry) if entry else {}
    out = {op: acc.get(op, 0.0) for op in _COLL_OPS}
    out["count"] = int(acc.get("count", 0))
    out["total"] = sum(out[op] for op in _COLL_OPS)
    out["flops"] = acc.get("flops", 0.0)
    out["bytes"] = acc.get("bytes", 0.0)
    return out


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    stats = hlo_stats(hlo_text)
    return {k: v for k, v in stats.items() if k not in ("flops", "bytes")}


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    n_chips: int
    hlo_flops: float                 # per device
    hlo_bytes: float                 # per device
    coll_bytes: float                # per device
    coll_breakdown: Dict[str, float]
    t_compute: float
    t_memory: float
    t_collective: float
    model_flops: float               # 6·N_active·D global
    peak_bytes_per_device: Optional[float] = None

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time_bound(self) -> float:
        """Lower bound on step time = max of the three terms."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (global HLO flops) — remat/redundancy waste."""
        total = self.hlo_flops * self.n_chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """compute term / max term — 1.0 means perfectly compute-bound."""
        b = self.step_time_bound
        return self.t_compute / b if b else 0.0

    def row(self) -> str:
        return (f"{self.arch},{self.shape},{self.mesh},{self.n_chips},"
                f"{self.hlo_flops:.3e},{self.hlo_bytes:.3e},"
                f"{self.coll_bytes:.3e},{self.t_compute*1e3:.3f},"
                f"{self.t_memory*1e3:.3f},{self.t_collective*1e3:.3f},"
                f"{self.dominant},{self.useful_flops_ratio:.3f},"
                f"{self.roofline_fraction:.3f}")


HEADER = ("arch,shape,mesh,chips,hlo_flops/dev,hlo_bytes/dev,"
          "coll_bytes/dev,t_compute_ms,t_memory_ms,t_coll_ms,"
          "dominant,useful_flops_ratio,roofline_fraction")


def analyze(arch: str, shape: str, mesh_name: str, n_chips: int,
            cost: Dict[str, float], hlo_text: str, model_flops: float,
            chip: ChipSpec = V5E,
            memory_stats: Optional[object] = None) -> RooflineReport:
    # NOTE: XLA's cost_analysis() counts while bodies ONCE (verified with
    # a scan-of-matmuls probe) — useless for scan-over-layers programs.
    # We use the trip-count-aware analyzer; `cost` is kept for
    # cross-checking in EXPERIMENTS.md §Dry-run.
    coll = hlo_stats(hlo_text)
    flops = coll["flops"]
    byts = coll["bytes"]
    # ICI vs DCN: inter-pod collectives (axis `pod`) are tagged by the
    # launcher via mesh_name; the conservative charge here uses ICI for
    # all (DCN correction applied by the launcher when pod axis is used).
    ici_bw = chip.ici_link_bw * chip.ici_links_per_axis
    rep = RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, n_chips=n_chips,
        hlo_flops=flops, hlo_bytes=byts, coll_bytes=coll["total"],
        coll_breakdown=coll,
        t_compute=flops / chip.peak_flops_bf16,
        t_memory=byts / chip.hbm_bw,
        t_collective=coll["total"] / ici_bw,
        model_flops=model_flops,
    )
    if memory_stats is not None:
        try:
            rep.peak_bytes_per_device = float(
                memory_stats.temp_size_in_bytes
                + memory_stats.argument_size_in_bytes
                + memory_stats.output_size_in_bytes)
        except Exception:
            pass
    return rep
