"""Target hardware model: TPU v5e pod (the simulation/roofline substrate).

All DistSim analytical event times and every roofline term in
EXPERIMENTS.md derive from these constants. The container has no TPU —
these describe the TARGET, per the assignment:

    197 TFLOP/s bf16 per chip; 819 GB/s HBM; ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    name: str = "tpu-v5e"
    peak_flops_bf16: float = 197e12          # FLOP/s per chip
    hbm_bw: float = 819e9                    # bytes/s
    hbm_bytes: float = 16e9                  # HBM capacity per chip
    vmem_bytes: float = 128 * 2 ** 20        # ~128 MiB VMEM
    ici_link_bw: float = 50e9                # bytes/s per ICI link (one dir)
    ici_links_per_axis: int = 2              # bidirectional ring → 2 links
    dcn_bw: float = 25e9                     # bytes/s per host inter-pod (DCN)
    mxu_dim: int = 128                       # systolic array side
    # launch/fusion fixed overhead per HLO op (s). Calibratable.
    op_overhead: float = 2e-6
    # collective latency term per hop (s)
    ici_hop_latency: float = 1e-6
    dcn_latency: float = 25e-6


V5E = ChipSpec()


def mxu_efficiency(m: int, n: int, k: int, spec: ChipSpec = V5E) -> float:
    """Fraction of peak a GEMM of logical dims (m,n,k) achieves.

    TPU systolic arrays lose throughput when dims are not multiples of the
    MXU tile and when the surface-to-volume ratio is bad (small dims).
    This simple two-factor model is the analytical provider's efficiency
    curve; MeasuredProvider replaces it with real timings.
    """
    d = spec.mxu_dim

    def align(x: int) -> float:
        if x >= d:
            full = (x // d) * d
            return max(full / x, 0.75)        # ragged tail wastes a tile
        return max(x / d, 0.05)               # under-filled systolic array

    a = align(m) * align(n) * align(k)
    # small-matrix pipeline fill/drain penalty
    depth = min(m, n, k)
    fill = depth / (depth + d)
    return max(0.04, min(0.95, a * (0.5 + 0.5 * fill) * 0.85))
