"""Mega-batch predict: differential oracle against per-engine run(),
backend agreement, and edge cases (PR: vectorized strategy scoring)."""
import numpy as np
import pytest

from repro.configs.base import get_config, smoke_config
from repro.core import (A40_CLUSTER, AnalyticalProvider, DistSim, Strategy,
                        MegaBatch, megabatch_predict)

PROVIDER = AnalyticalProvider(A40_CLUSTER)
CFG = get_config("gpt2_345m")

# fully heterogeneous: schedules, pp depth, vpp, microbatches, zero1,
# grad compression — and ragged task counts (2 .. hundreds of tasks)
STRATS = [
    Strategy(mp=1, pp=1, dp=1, microbatches=1),
    Strategy(mp=1, pp=2, dp=2, microbatches=4),
    Strategy(mp=1, pp=4, dp=1, microbatches=8, schedule="gpipe"),
    Strategy(mp=2, pp=2, dp=1, microbatches=4, schedule="interleaved",
             vpp=2),
    Strategy(mp=1, pp=2, dp=2, microbatches=4, schedule="pipedream"),
    Strategy(mp=2, pp=2, dp=2, microbatches=4, zero1=True),
    Strategy(mp=1, pp=4, dp=2, microbatches=16, schedule="interleaved",
             vpp=3),
    Strategy(mp=1, pp=2, dp=2, microbatches=4, grad_compress=0.25),
    Strategy(mp=1, pp=8, dp=1, microbatches=8),
]


def _engines(cfg=CFG, strats=STRATS, seq=128):
    engines = []
    for strat in strats:
        gb = strat.dp * strat.microbatches * 2
        engines.append(DistSim(cfg, strat, gb, seq, PROVIDER).engine())
    return engines


def test_megabatch_bit_identical_to_per_engine_run():
    """The tentpole gate: batch times bit-identical PER CANDIDATE to
    engine.run(), across heterogeneous ragged candidates."""
    engines = _engines()
    sizes = {e.total_tasks for e in engines}
    assert len(sizes) > 3            # genuinely ragged program
    pred = megabatch_predict(engines, backend="numpy")
    assert pred.backend == "numpy"
    assert pred.n_candidates == len(engines)
    for i, eng in enumerate(engines):
        tl = eng.run()
        assert float(pred.batch_times[i]) == tl.batch_time, \
            eng.strat.label()
        assert float(pred.bubble_fractions[i]) == pytest.approx(
            tl.bubble_fraction(), abs=1e-12)


def test_megabatch_includes_empty_stage_candidates():
    """pp > layer count: candidates whose trailing devices own no
    tasks still score bit-identically."""
    cfg = smoke_config(get_config("gpt2_345m"))      # 2 layers
    strats = [Strategy(pp=4, microbatches=4),
              Strategy(pp=2, microbatches=2),
              Strategy(pp=8, microbatches=8, schedule="gpipe")]
    engines = _engines(cfg, strats, seq=64)
    pred = megabatch_predict(engines, backend="numpy")
    for i, eng in enumerate(engines):
        assert float(pred.batch_times[i]) == eng.run().batch_time


def test_megabatch_empty_and_single():
    empty = MegaBatch([]).predict()
    assert empty.n_candidates == 0 and len(empty.batch_times) == 0
    engines = _engines(strats=STRATS[:1])
    pred = MegaBatch(engines).predict("numpy")
    assert float(pred.batch_times[0]) == engines[0].run().batch_time


def test_megabatch_compile_once_predict_many():
    engines = _engines(strats=STRATS[:4])
    mb = MegaBatch(engines)
    a = mb.predict("numpy").batch_times
    b = mb.predict("numpy").batch_times
    assert np.array_equal(a, b)
    assert np.array_equal(a, mb.predict_times("numpy"))


def test_megabatch_unknown_backend_raises():
    mb = MegaBatch(_engines(strats=STRATS[:1]))
    with pytest.raises(ValueError, match="backend"):
        mb.predict("cuda")


def test_megabatch_auto_backend_numpy_without_accelerator():
    """'auto' must not import jax on a CPU box (numpy-only CI jobs)."""
    mb = MegaBatch(_engines(strats=STRATS[:1]))
    assert mb.resolve_backend("auto") in ("numpy", "jax")


@pytest.mark.parametrize("backend", ["jax", "pallas"])
def test_megabatch_accelerator_backends_match_numpy(backend):
    """jax/pallas run the same recurrence; float32 accumulation bounds
    the deviation (numpy stays the bit-identical reference)."""
    jax = pytest.importorskip("jax")
    del jax
    engines = _engines(strats=STRATS[:5])
    mb = MegaBatch(engines)
    ref = mb.predict("numpy").batch_times
    got = mb.predict(backend)
    assert got.backend == backend
    np.testing.assert_allclose(got.batch_times, ref, rtol=1e-5)
