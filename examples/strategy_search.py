"""Paper §6 use-case: automatic hybrid-parallel strategy search.

Searches (MP, PP, DP, microbatches) for BERT-exLarge on 16 devices
without touching a cluster, then verifies the top pick against the
replay oracle — the workflow of Fig. 12 / Table 2.

    PYTHONPATH=src python examples/strategy_search.py [--devices 16]
"""
import argparse

from repro.configs.base import get_config
from repro.core import (A40_CLUSTER, AnalyticalProvider, DistSim,
                        grid_search)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=16)
    ap.add_argument("--global-batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--arch", default="bert_exlarge")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    provider = AnalyticalProvider(A40_CLUSTER)
    entries = grid_search(cfg, args.devices, args.global_batch, args.seq,
                          provider=provider,
                          schedules=("1f1b", "gpipe", "interleaved"))
    feasible = [e for e in entries if e.feasible]

    print(f"{args.arch} on {args.devices} devices, "
          f"global batch {args.global_batch}: "
          f"{len(feasible)} feasible strategies\n")
    print(f"{'strategy':14s} {'sched':12s} {'micro':>5s} {'it/s':>8s} "
          f"{'bubble%':>8s}")
    for e in feasible[:10]:
        print(f"{e.strategy.label():14s} {e.strategy.schedule:12s} "
              f"{e.strategy.microbatches:5d} {e.iters_per_s:8.2f} "
              f"{e.bubble_fraction*100:8.1f}")
    worst = feasible[-1]
    print(f"...\n{'WORST: ' + worst.strategy.label():14s} "
          f"{worst.strategy.schedule:12s} "
          f"{worst.strategy.microbatches:5d} {worst.iters_per_s:8.3f}")
    print(f"\nbest/worst speedup: "
          f"{worst.batch_time/feasible[0].batch_time:.2f}x "
          f"(paper found 7.379x)")

    best = feasible[0]
    act = DistSim(cfg, best.strategy, args.global_batch, args.seq,
                  provider).replay(seed=0)
    print(f"replay-verified best: {1/act.batch_time:.2f} it/s")


if __name__ == "__main__":
    main()
