"""Fault tolerance & elasticity for 1000+-node runs.

Components (all exercised by tests/test_fault_tolerance.py):

* ``HeartbeatMonitor`` — per-worker step-time tracking; flags stragglers
  (step time > straggler_factor x rolling median) and dead workers
  (missed heartbeats). On TPU pods the equivalent signal comes from the
  coordination service; the policy layer is identical.

* ``ElasticPlan`` — given the surviving device count, re-solve the mesh
  (largest (data, model) grid that divides the survivors, preferring to
  keep `model` intact since TP re-sharding moves the most weight bytes)
  and re-shard from the last checkpoint. DistSim itself (repro.core) is
  used to pick the best strategy for the NEW world size — the paper's
  §6 use-case applied to failure recovery.

* ``run_with_recovery`` — driver loop: on simulated failure, restores
  the latest checkpoint, rebuilds the mesh, continues. Guarantees
  at-most-`save_every` lost steps.

Straggler mitigation: within-step, TPU SPMD is bulk-synchronous, so the
mitigation is (a) flagging for re-scheduling, (b) excluding the rank at
the next elastic re-plan — both implemented here; (c) microbatch-level
work re-balancing is a DistSim what-if query (bench_straggler).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class WorkerState:
    last_heartbeat: float
    step_times: List[float] = dataclasses.field(default_factory=list)
    alive: bool = True


class HeartbeatMonitor:
    def __init__(self, n_workers: int, straggler_factor: float = 1.5,
                 dead_after_s: float = 60.0, window: int = 16):
        self.workers: Dict[int, WorkerState] = {
            i: WorkerState(last_heartbeat=0.0) for i in range(n_workers)}
        self.straggler_factor = straggler_factor
        self.dead_after_s = dead_after_s
        self.window = window

    def heartbeat(self, worker: int, step_time: float,
                  now: Optional[float] = None):
        """Record a step heartbeat. A heartbeat from a worker
        previously marked dead re-joins it (elastic rescheduling
        brought the node back); its stale step-time history is dropped
        so straggler detection starts fresh."""
        w = self.workers[worker]
        if not w.alive:
            w.alive = True
            w.step_times.clear()
        w.last_heartbeat = now if now is not None else time.time()
        w.step_times.append(step_time)
        if len(w.step_times) > self.window:
            w.step_times.pop(0)

    def stragglers(self) -> List[int]:
        med = np.median([np.mean(w.step_times)
                         for w in self.workers.values()
                         if w.step_times and w.alive] or [0.0])
        if med == 0.0:
            return []
        return [i for i, w in self.workers.items()
                if w.alive and w.step_times
                and np.mean(w.step_times) > self.straggler_factor * med]

    def dead(self, now: Optional[float] = None) -> List[int]:
        """Pure query: workers currently overdue (alive but silent for
        longer than ``dead_after_s``). Does NOT change state — call
        :meth:`mark_dead` to transition them, so callers that poll
        twice (or several pollers sharing one monitor) all see the
        same death."""
        now = now if now is not None else time.time()
        return [i for i, w in self.workers.items()
                if w.alive and now - w.last_heartbeat > self.dead_after_s]

    def mark_dead(self, workers: Optional[List[int]] = None,
                  now: Optional[float] = None) -> List[int]:
        """State transition: mark ``workers`` (default: the current
        :meth:`dead` set) as dead; returns the workers actually
        transitioned. A later :meth:`heartbeat` re-joins them."""
        targets = self.dead(now) if workers is None else workers
        out = []
        for i in targets:
            w = self.workers[i]
            if w.alive:
                w.alive = False
                out.append(i)
        return out

    def alive_count(self) -> int:
        return sum(w.alive for w in self.workers.values())


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    data: int
    model: int

    @property
    def devices(self) -> int:
        return self.data * self.model


def replan_mesh(survivors: int, model_parallel: int) -> ElasticPlan:
    """Largest usable (data, model) grid after failures.

    Keeps `model` intact if possible (TP re-sharding moves the most
    bytes); drops to the largest power-of-two data degree that fits.
    """
    if survivors < 1:
        raise ValueError(
            f"replan_mesh needs at least one survivor, got {survivors}")
    mp = model_parallel
    while mp > 1 and survivors < mp:
        mp //= 2
    data = 1
    while data * 2 * mp <= survivors:
        data *= 2
    return ElasticPlan(data=data, model=mp)


def run_with_recovery(n_steps: int,
                      step_fn: Callable[[int], float],
                      save_fn: Callable[[int], None],
                      restore_fn: Callable[[], int],
                      save_every: int = 10,
                      failure_at: Optional[int] = None,
                      max_recoveries: int = 8) -> Tuple[int, int]:
    """Driver with checkpoint/restart. ``step_fn(step)`` may raise
    RuntimeError (simulated node failure); we restore and continue.
    Returns (completed_steps, n_recoveries).

    ``max_recoveries`` bounds the restart budget: a persistent failure
    (e.g. a step that deterministically raises) would otherwise loop
    forever, since ``restore_fn`` rewinds to the same step each time.
    When the budget is exhausted the last failure is re-raised with
    recovery context chained on it."""
    recoveries = 0
    step = restore_fn()
    while step < n_steps:
        try:
            if failure_at is not None and step == failure_at:
                failure_at = None          # fail exactly once
                raise RuntimeError("simulated node failure")
            step_fn(step)
            step += 1
            if step % save_every == 0:
                save_fn(step)
        except RuntimeError as exc:
            recoveries += 1
            if recoveries > max_recoveries:
                raise RuntimeError(
                    f"persistent failure at step {step}: recovery "
                    f"budget exhausted after {max_recoveries} "
                    f"recoveries") from exc
            step = restore_fn()
    return step, recoveries
