"""Train/serve step factories — the functions the launcher jits & lowers.

``make_train_step`` returns a full production step: loss → grad →
(optional gradient-accumulation scan over microbatches) → global-norm
clip → AdamW update. ``make_serve_step`` returns the one-token decode
step. Both are pure and pjit-compatible.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.api import build_model
from repro.models.layers import ModelOptions, DEFAULT_OPTIONS
from repro.train import optimizer as opt


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    adamw: opt.AdamWConfig = opt.AdamWConfig()
    accum_steps: int = 1              # gradient-accumulation microbatches


def make_train_step(cfg: ArchConfig, opts: ModelOptions = DEFAULT_OPTIONS,
                    tcfg: TrainConfig = TrainConfig(),
                    grad_specs: Optional[Any] = None) -> Callable:
    api = build_model(cfg, opts)

    def loss_fn(params, batch):
        return api.loss(params, batch)

    def train_step(params, opt_state, batch):
        if tcfg.accum_steps == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            # split batch into microbatches along dim0 and scan-accumulate
            a = tcfg.accum_steps

            def split(x):
                return x.reshape((a, x.shape[0] // a) + x.shape[1:])

            micro = jax.tree.map(split, batch)

            def acc(carry, mb):
                tot_l, tot_g = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                return (tot_l + l,
                        jax.tree.map(jnp.add, tot_g, g)), None

            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                acc, (jnp.zeros((), jnp.float32), zero_g), micro)
            loss = loss / a
            grads = jax.tree.map(lambda g: g / a, grads)

        if grad_specs is not None:
            # pin gradient sharding to the parameter sharding BEFORE the
            # optimizer — prevents XLA from resolving mismatched layouts
            # with full-weight f32 all-gathers
            grads = jax.lax.with_sharding_constraint(grads, grad_specs)
            # barrier: stops XLA from hoisting the optimizer's f32
            # converts above the gradient reduction (measured: f32
            # all-reduce instead of bf16 — 2x wire bytes; §Perf C1)
            grads = jax.lax.optimization_barrier(grads)
        new_params, new_state, metrics = opt.update(
            tcfg.adamw, params, grads, opt_state)
        metrics = {"loss": loss, **metrics}
        return new_params, new_state, metrics

    return train_step


def make_prefill_step(cfg: ArchConfig,
                      opts: ModelOptions = DEFAULT_OPTIONS) -> Callable:
    api = build_model(cfg, opts)

    def prefill_step(params, batch):
        return api.forward(params, batch)

    return prefill_step


def make_serve_step(cfg: ArchConfig,
                    opts: ModelOptions = DEFAULT_OPTIONS) -> Callable:
    api = build_model(cfg, opts)

    def serve_step(params, cache, batch):
        return api.decode_step(params, cache, batch)

    return serve_step
