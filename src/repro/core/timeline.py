"""Per-device activity timeline — DistSim's output artifact (paper Fig. 6).

Activities carry (device, kind, stage, micro, start, end); utilities
compute batch time, per-device busy/idle, bubble fraction, and the
paper's evaluation metrics (batch-time error, per-device activity error,
per-stage timestamp error).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple


@dataclasses.dataclass(frozen=True)
class Activity:
    device: int
    name: str              # e.g. "F:s2:m5"
    kind: str              # F | B | P2P | AR | OPT
    start: float
    end: float
    stage: int = -1
    micro: int = -1

    @property
    def dur(self) -> float:
        return self.end - self.start


@dataclasses.dataclass
class Timeline:
    activities: List[Activity]
    n_devices: int

    @property
    def batch_time(self) -> float:
        return max((a.end for a in self.activities), default=0.0)

    def by_device(self) -> Dict[int, List[Activity]]:
        out: Dict[int, List[Activity]] = {d: [] for d in range(self.n_devices)}
        for a in self.activities:
            out[a.device].append(a)
        for v in out.values():
            v.sort(key=lambda a: a.start)
        return out

    def busy_time(self, device: int, kinds=("F", "B", "AR", "OPT")) -> float:
        return sum(a.dur for a in self.activities
                   if a.device == device and a.kind in kinds)

    def utilization(self) -> Dict[int, float]:
        bt = self.batch_time or 1.0
        return {d: self.busy_time(d) / bt for d in range(self.n_devices)}

    def bubble_fraction(self) -> float:
        util = self.utilization()
        return 1.0 - sum(util.values()) / max(1, len(util))

    def compute_index(self) -> Dict[Tuple[int, str], Activity]:
        """(device, name) → activity, compute events only."""
        return {(a.device, a.name): a for a in self.activities
                if a.kind in ("F", "B")}


# --------------------------------------------------------------------------
# evaluation metrics (paper §5)
# --------------------------------------------------------------------------

def batch_time_error(pred: Timeline, actual: Timeline) -> float:
    """§5.2 relative iteration-time error."""
    at = actual.batch_time
    return abs(pred.batch_time - at) / at if at else 0.0


def activity_error(pred: Timeline, actual: Timeline) -> Dict[int, float]:
    """§5.3: per-device mean |timestamp bias| of compute events,
    normalized by actual batch time."""
    ai = actual.compute_index()
    bt = actual.batch_time or 1.0
    per_dev: Dict[int, List[float]] = {}
    for key, p in pred.compute_index().items():
        a = ai.get(key)
        if a is None:
            continue
        err = 0.5 * (abs(p.start - a.start) + abs(p.end - a.end)) / bt
        per_dev.setdefault(key[0], []).append(err)
    return {d: sum(v) / len(v) for d, v in per_dev.items() if v}


def per_stage_error(pred: Timeline, actual: Timeline
                    ) -> Dict[Tuple[int, str], float]:
    """§5.4: per (device, F/B:stage:micro) timestamp error."""
    ai = actual.compute_index()
    bt = actual.batch_time or 1.0
    out = {}
    for key, p in pred.compute_index().items():
        a = ai.get(key)
        if a is not None:
            out[key] = 0.5 * (abs(p.start - a.start)
                              + abs(p.end - a.end)) / bt
    return out


def to_chrome_trace(tl: Timeline, path: str) -> None:
    """Export a timeline as a Chrome trace (chrome://tracing /
    Perfetto). One row per device; compute/comm events color-coded by
    phase."""
    import json
    events = []
    for a in tl.activities:
        events.append({
            "name": a.name, "ph": "X",
            "ts": a.start * 1e6, "dur": max(a.dur * 1e6, 0.01),
            "pid": 0, "tid": a.device,
            "cat": a.kind,
            "args": {"stage": a.stage, "micro": a.micro},
        })
    meta = [{"name": "thread_name", "ph": "M", "pid": 0, "tid": d,
             "args": {"name": f"device {d}"}}
            for d in range(tl.n_devices)]
    with open(path, "w") as f:
        json.dump({"traceEvents": meta + events}, f)
