"""Finding/severity plumbing shared by both analysis passes.

This module is deliberately import-free of :mod:`repro.core` so the
engine and megabatch constructors can reach :func:`default_verify` /
:class:`GraphInvariantError` without any import cycle: the verifier
itself (:mod:`repro.analyze.graph`) is imported lazily, only when a
construction actually asks to be verified.
"""
from __future__ import annotations

import dataclasses
import os
from typing import List, Optional, Sequence


@dataclasses.dataclass(frozen=True)
class Finding:
    """One analyzer diagnostic.

    ``rule`` is a stable machine-checkable identifier (``Gxxx`` for
    graph-verifier rules, ``Lxxx`` for source-lint rules — the mutation
    suite asserts on these, so renames are breaking). ``where`` locates
    the finding: ``path:line`` for lint, an engine/cell label for graph
    checks.
    """
    rule: str
    message: str
    where: str = ""
    severity: str = "error"

    def __str__(self) -> str:
        loc = f" [{self.where}]" if self.where else ""
        return f"{self.rule}{loc}: {self.message}"


class GraphInvariantError(RuntimeError):
    """Raised by ``verify=``-enabled construction when the static
    verifier finds a broken invariant. Carries the full finding list —
    the message shows every finding, not just the first."""

    def __init__(self, findings: Sequence[Finding]):
        self.findings: List[Finding] = list(findings)
        lines = "\n  ".join(str(f) for f in self.findings)
        super().__init__(
            f"{len(self.findings)} graph invariant violation(s):\n"
            f"  {lines}")


#: environment switch for the construction-time verifier. Tests/CI set
#: it (``tests/conftest.py``, the CI job env); hot paths leave it unset
#: so predict/search throughput pays nothing.
VERIFY_ENV = "REPRO_VERIFY"

_TRUTHY = ("1", "true", "yes", "on")


def default_verify(flag: Optional[bool] = None) -> bool:
    """Resolve a constructor's ``verify=`` argument.

    An explicit ``True``/``False`` wins; ``None`` (the default on every
    call site) defers to the :data:`VERIFY_ENV` environment variable —
    off unless set to a truthy value.
    """
    if flag is not None:
        return flag
    return os.environ.get(VERIFY_ENV, "").strip().lower() in _TRUTHY


def raise_on_findings(findings: Sequence[Finding]) -> None:
    """Raise :class:`GraphInvariantError` iff any finding is an error."""
    errors = [f for f in findings if f.severity == "error"]
    if errors:
        raise GraphInvariantError(errors)
