"""Paper §6 use-case: automatic hybrid-parallel strategy search.

Sweeps (MP, PP, DP, microbatches, schedule) for a model WITHOUT touching
a cluster — the Fig. 12 / Table 2 workflow — using the cached, pruned
search engine:

* every candidate shares one profile cache per cluster, so unique
  events are cost-evaluated once per search, not once per candidate;
* memory-infeasible candidates are skipped, and candidates whose
  work lower bound already loses to the best known strategy are pruned
  before full timeline construction;
* pass several ``--clusters`` to get per-cluster rankings plus a
  cross-cluster Pareto frontier over (batch time, HBM headroom,
  profiling cost) — e.g. "fastest on A40, but v5e leaves 2x the
  activation headroom".

    PYTHONPATH=src python examples/strategy_search.py \
        [--devices 16] [--clusters a40-cluster,v5e-pod] [--no-prune]

The top pick is re-checked against the replay oracle (jittered
discrete-event run), as the paper validates Table 2 on real hardware.
"""
import argparse

from repro.configs.base import get_config
from repro.core import DistSim, get_cluster
from repro.search import SearchEngine, format_report, search_report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=16)
    ap.add_argument("--global-batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--arch", default="bert_exlarge")
    ap.add_argument("--clusters", default="a40-cluster",
                    help="comma-separated ClusterSpec names "
                         "(a40-cluster, v5e-pod)")
    ap.add_argument("--no-prune", action="store_true",
                    help="simulate every candidate (cross-check mode)")
    ap.add_argument("--top", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    clusters = [get_cluster(n) for n in args.clusters.split(",")]
    engine = SearchEngine(cfg, clusters=clusters,
                          prune=not args.no_prune, check_memory=True)
    result = engine.search(args.devices, args.global_batch, args.seq,
                           schedules=("1f1b", "gpipe", "interleaved"))

    print(f"{args.arch} on {args.devices} devices, "
          f"global batch {args.global_batch}, "
          f"clusters {[c.name for c in clusters]}\n")
    print(format_report(search_report(result, top=args.top)))

    best = result.best()
    if best is None:
        print("\nno feasible strategy found")
        return
    cluster = next(c for c in clusters if c.name == best.cluster)
    provider = engine.cache.provider(cluster)
    act = DistSim(cfg, best.strategy, args.global_batch, args.seq,
                  provider).simulate(seeds=0).result()
    print(f"\nreplay-verified best ({best.strategy.label()} on "
          f"{best.cluster}): {1 / act.batch_time:.2f} it/s "
          f"(predicted {best.iters_per_s:.2f})")


if __name__ == "__main__":
    main()
