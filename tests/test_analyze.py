"""repro.analyze: mutation suite + clean-run assertions.

Each test seeds exactly one defect class from the rule tables in
:mod:`repro.analyze.graph` (G001..G010) / :mod:`repro.analyze.lint`
(L001..L004) and asserts the INTENDED rule fires — not merely "some
finding appears". The clean-run half asserts zero findings over every
in-tree smoke-matrix cell (train + serving + degraded), the compiled
mega-batch program, and the real source tree: the verifier earns its
keep only if it is silent on healthy graphs.
"""
import dataclasses
import inspect

import pytest

from repro.analyze import (GraphInvariantError, default_verify,
                           lint_paths, lint_source, raise_on_findings,
                           verify_build, verify_cell_memory,
                           verify_engine, verify_megabatch,
                           verify_perturbation)
from repro.analyze.findings import Finding
from repro.configs.base import get_config
from repro.core import (A40_CLUSTER, AnalyticalProvider, DistSim, Fault,
                        MegaBatch, Perturbation, Strategy)
from repro.validate import (BuildCache, degraded_matrix, serving_matrix,
                            smoke_matrix)

CFG = get_config("gpt2_345m")
PROVIDER = AnalyticalProvider(A40_CLUSTER)


def _engine(mp=1, pp=2, dp=1, m=4, schedule="1f1b"):
    """A small engine built with verification OFF so tests can mutate
    it into each defect class before calling the verifier."""
    strat = Strategy(mp=mp, pp=pp, dp=dp, microbatches=m,
                     schedule=schedule)
    sim = DistSim(CFG, strat, dp * m, 128, PROVIDER)
    from repro.core.engine import EventFlowEngine
    return EventFlowEngine(sim.positions(), strat, PROVIDER,
                           verify=False)


def _rules(findings):
    return {f.rule for f in findings}


# --------------------------------------------------------------------------
# graph verifier: seeded defects
# --------------------------------------------------------------------------

def test_clean_engine_has_no_findings():
    assert verify_engine(_engine()) == []


def test_g001_dependency_cycle():
    """Reversing one device's task list makes its head task wait on an
    arrival its own later tasks produce — a cycle through the device-
    serialization chain. Exactly G001 fires; topo_order() is never
    consulted on a cyclic graph (it deadlocks by design, G004 stays
    quiet)."""
    eng = _engine()
    for lst in (eng.task_isf, eng.task_pos, eng.task_micro,
                eng.task_name, eng.task_p2p_name):
        lst[1] = lst[1][::-1]
    eng._topo = None
    assert _rules(verify_engine(eng)) == {"G001"}


def test_g002_dangling_dependency():
    """Retag one F task to a microbatch that doesn't exist: its B
    consumer now depends on a producer no task provides (G002), and
    coverage reports the original slot missing (G003)."""
    eng = _engine()
    d = 1
    idx = next(i for i, isf in enumerate(eng.task_isf[d]) if isf)
    eng.task_micro[d] = list(eng.task_micro[d])
    eng.task_micro[d][idx] = eng.m + 5
    eng._topo = None
    rules = _rules(verify_engine(eng))
    assert "G002" in rules
    assert "G003" in rules            # the (F, pos, mic) slot went missing


def test_g003_duplicate_and_misplaced_task():
    eng = _engine()
    d = 0
    # duplicate: copy device 1's first task onto device 0 — same
    # (phase, pos, mic) key now has two producers, and the copy sits on
    # a device its position does not map to
    eng.task_isf[d] = list(eng.task_isf[d]) + [eng.task_isf[1][0]]
    eng.task_pos[d] = list(eng.task_pos[d]) + [eng.task_pos[1][0]]
    eng.task_micro[d] = list(eng.task_micro[d]) + [eng.task_micro[1][0]]
    eng.task_name[d] = list(eng.task_name[d]) + [eng.task_name[1][0]]
    eng.task_p2p_name[d] = list(eng.task_p2p_name[d]) \
        + [eng.task_p2p_name[1][0]]
    eng._topo = None
    assert "G003" in _rules(verify_engine(eng))


def test_g004_stale_topo_order():
    """A topo_order() that disagrees with the true edges (here: served
    stale after a task-list edit) is the MegaBatch compile contract
    breaking — G004."""
    eng = _engine()
    eng._topo = list(reversed(eng.topo_order()))
    assert "G004" in _rules(verify_engine(eng))


def test_g006_metadata_misalignment():
    eng = _engine()
    eng.task_name[0] = list(eng.task_name[0])[:-1]    # drop one entry
    assert _rules(verify_engine(eng)) == {"G006"}


def test_g009_non_finite_event_mean():
    eng = _engine()
    eng.build.fwd_event_means[0] = [float("nan")] \
        + list(eng.build.fwd_event_means[0][1:])
    assert "G009" in _rules(verify_engine(eng))
    # a bare build (no schedule) gets the same means check
    assert "G009" in _rules(verify_build(eng.build))


# --------------------------------------------------------------------------
# megabatch program: seeded defects
# --------------------------------------------------------------------------

def _megabatch():
    cache = BuildCache(PROVIDER)
    engines = [cache.engine_for(c) for c in smoke_matrix()[:3]]
    return MegaBatch(engines, verify=False)


def test_clean_megabatch_has_no_findings():
    assert verify_megabatch(_megabatch()) == []


def test_g005_write_before_read():
    """The >3-deps defect class: an extra (unhonorable) dependency
    compiles into a dep plane reading a slot written at a LATER step of
    the same candidate. G005 catches it as write-before-read."""
    mb = _megabatch()
    k = 1
    n = mb.engines[k].total_tasks
    # point step 0's dep1 at the slot written by this candidate's LAST
    # step — a forward reference no schedule can honor
    mb._dep1[0, k] = mb._out[n - 1, k]
    assert "G005" in _rules(verify_megabatch(mb))


def test_g005_foreign_candidate_read():
    mb = _megabatch()
    mb._dep2[0, 0] = mb._out[0, 1]     # candidate 0 reads candidate 1
    assert "G005" in _rules(verify_megabatch(mb))


def test_g006_broken_serialization_chain():
    mb = _megabatch()
    k = 0
    n = mb.engines[k].total_tasks
    # retarget a mid-chain dep0 to the dummy slot: the chain breaks and
    # an extra chain head appears
    mb._dep0[n // 2, k] = 0
    assert "G006" in _rules(verify_megabatch(mb))


def test_g005_negative_duration():
    mb = _megabatch()
    mb._dur[0, 0] = -1.0
    assert "G005" in _rules(verify_megabatch(mb))


# --------------------------------------------------------------------------
# perturbation + memory: seeded defects
# --------------------------------------------------------------------------

def test_g008_fault_rank_outside_mesh():
    strat = Strategy(mp=1, pp=2, dp=2, microbatches=4)
    p = Perturbation(faults=(Fault(rank=99, at_step=1),), steps=8)
    assert "G008" in _rules(verify_perturbation(p, strat))


def test_g008_unrecoverable_fault():
    """world = mp*pp = 4 with dp=1: losing any rank leaves 3 survivors,
    which cannot hold the 4-wide model group — replan must fail."""
    strat = Strategy(mp=2, pp=2, dp=1, microbatches=4)
    p = Perturbation(faults=(Fault(rank=1, at_step=1),), steps=8)
    assert "G008" in _rules(verify_perturbation(p, strat))


def test_g008_clean_survivable_fault():
    strat = Strategy(mp=1, pp=2, dp=2, microbatches=4)
    p = Perturbation(faults=(Fault(rank=3, at_step=2),), steps=8)
    assert verify_perturbation(p, strat) == []


def test_g010_over_capacity_strategy():
    """An unsharded 145B model cannot fit a single 48 GB chip."""
    cfg = get_config("gpt_145b")
    strat = Strategy(mp=1, pp=1, dp=1, microbatches=1)
    fs = verify_cell_memory(cfg, strat, 4, 2048,
                            A40_CLUSTER.chip.hbm_bytes)
    assert _rules(fs) == {"G010"}


def test_g010_clean_fitting_strategy():
    strat = Strategy(mp=2, pp=2, dp=1, microbatches=4)
    assert verify_cell_memory(CFG, strat, 1, 128,
                              A40_CLUSTER.chip.hbm_bytes) == []


# --------------------------------------------------------------------------
# construction-time wiring (verify= flag / REPRO_VERIFY)
# --------------------------------------------------------------------------

def test_verify_flag_raises_at_construction(monkeypatch):
    """With verify on, a corrupted build fails AT CONSTRUCTION with all
    findings in the error — not later as a silent mis-simulation."""
    eng = _engine()
    eng.build.p2p_base = float("inf")
    from repro.core.engine import EventFlowEngine
    with pytest.raises(GraphInvariantError, match="G009"):
        EventFlowEngine(eng.stages, eng.strat, PROVIDER,
                        build=eng.build, verify=True)
    # verify=False skips the check even with the env var set
    monkeypatch.setenv("REPRO_VERIFY", "1")
    EventFlowEngine(eng.stages, eng.strat, PROVIDER, build=eng.build,
                    verify=False)


def test_default_verify_env_semantics(monkeypatch):
    monkeypatch.delenv("REPRO_VERIFY", raising=False)
    assert default_verify(None) is False
    assert default_verify(True) is True
    monkeypatch.setenv("REPRO_VERIFY", "1")
    assert default_verify(None) is True
    assert default_verify(False) is False
    monkeypatch.setenv("REPRO_VERIFY", "0")
    assert default_verify(None) is False


def test_raise_on_findings_severity():
    raise_on_findings([])                                    # no-op
    raise_on_findings([Finding(rule="GXXX", message="note",
                               severity="warning")])         # warnings pass
    with pytest.raises(GraphInvariantError):
        raise_on_findings([Finding(rule="GXXX", message="boom")])


# --------------------------------------------------------------------------
# source linter: seeded defects
# --------------------------------------------------------------------------

def test_l001_event_name_comparison():
    src = "def f(e):\n    if e.name == 'fwd':\n        return 1\n"
    assert _rules(lint_source(src, "src/repro/x.py")) == {"L001"}
    src2 = "def f(ev):\n    return ev.name.startswith('p2p')\n"
    assert _rules(lint_source(src2, "src/repro/x.py")) == {"L001"}


def test_l002_dropped_cache_key_field():
    """A frozen spec dataclass whose hand-written to_dict() omits a
    compared field: the serde key path no longer reaches it, so two
    distinct specs collide in the cache. L002."""
    src = (
        "import dataclasses\n"
        "@dataclasses.dataclass(frozen=True)\n"
        "class Spec:\n"
        "    mp: int = 1\n"
        "    pp: int = 1\n"
        "    zero1: bool = False\n"
        "    def to_dict(self):\n"
        "        return {'mp': self.mp, 'pp': self.pp}\n"
    )
    assert _rules(lint_source(src, "src/repro/x.py")) == {"L002"}
    # asdict-based to_dict reaches every field by construction
    fixed = src.replace("return {'mp': self.mp, 'pp': self.pp}",
                        "return dataclasses.asdict(self)")
    assert lint_source(fixed, "src/repro/x.py") == []


def test_l003_set_order_leak():
    """The exact pre-fix timeline.py pattern: iterating a set union
    into an ordered dict construction."""
    src = ("def f(pu, au):\n"
           "    return {d: pu.get(d, 0.0) - au.get(d, 0.0)\n"
           "            for d in set(pu) | set(au)}\n")
    assert _rules(lint_source(src, "src/repro/core/fake.py")) == {"L003"}
    fixed = src.replace("set(pu) | set(au)}",
                        "sorted(set(pu) | set(au))}")
    assert lint_source(fixed, "src/repro/core/fake.py") == []


def test_l003_scoped_to_core_and_store():
    src = "def f(s):\n    return tuple(x for x in s)\n"
    bad = "def f(s):\n    return tuple(x for x in set(s))\n"
    assert lint_source(bad, "src/repro/search/x.py") == []   # out of scope
    assert _rules(lint_source(bad, "src/repro/store/x.py")) == {"L003"}
    assert lint_source(src, "src/repro/store/x.py") == []


def test_l004_wallclock_and_unseeded_rng():
    src = "import time\ndef f():\n    return time.time()\n"
    assert _rules(lint_source(src, "src/repro/core/x.py")) == {"L004"}
    rng = "import numpy as np\ndef f():\n    return np.random.randn(3)\n"
    assert _rules(lint_source(rng, "src/repro/store/x.py")) == {"L004"}
    # profiler.py measures wall-clock by design — exempt
    assert lint_source(src, "src/repro/core/profiler.py") == []
    # seeded draws pass
    ok = ("import numpy as np\ndef f(seed):\n"
          "    return np.random.RandomState(seed).randn(3)\n")
    assert lint_source(ok, "src/repro/core/x.py") == []


def test_l000_syntax_error():
    assert _rules(lint_source("def f(:\n", "x.py")) == {"L000"}


# --------------------------------------------------------------------------
# clean runs: zero false positives over the real tree + smoke matrices
# --------------------------------------------------------------------------

def test_lint_clean_over_source_tree():
    assert lint_paths(["src/repro"]) == []


def test_timeline_util_delta_sorted_regression():
    """Satellite fix: _util_delta's key order is sorted, not set-hash
    order — and the module lints clean under L003."""
    from repro.core import timeline as tl
    assert lint_paths(["src/repro/core/timeline.py"]) == []
    src = inspect.getsource(tl._util_delta)
    assert "sorted" in src
    out = tl._util_delta({3: 0.5, 1: 0.25}, {2: 0.125})
    assert list(out) == [1, 2, 3]


_CACHE = BuildCache(PROVIDER)       # shared across matrix cells


@pytest.mark.parametrize("cell", smoke_matrix() + serving_matrix(),
                         ids=lambda c: c.label())
def test_clean_smoke_matrix_cell(cell):
    eng = _CACHE.engine_for(cell)
    assert verify_engine(eng) == []
    micro = cell.scenario.microbatch_size(cell.strategy, cell.global_batch)
    assert verify_cell_memory(cell.config(), cell.strategy, micro,
                              cell.seq, A40_CLUSTER.chip.hbm_bytes,
                              scenario=cell.scenario) == []


@pytest.mark.parametrize("cell", degraded_matrix(), ids=lambda c: c.label())
def test_clean_degraded_matrix_cell(cell):
    assert verify_engine(_CACHE.engine_for(cell)) == []
    assert verify_perturbation(cell.perturb, cell.strategy) == []


def test_frozen_spec_dataclasses_keep_key_paths():
    """The four spec dataclasses the cache keys ride on stay frozen and
    expose every compared field through their serde path (the linter's
    L002 contract, asserted directly against the live classes)."""
    from repro.core.costmodel import ClusterSpec
    from repro.core.events import Strategy as S
    from repro.core.perturb import Perturbation as P
    from repro.core.scenario import Decode
    for cls, obj in ((S, S(mp=2, pp=2, dp=2, zero1=True)),
                     (P, Perturbation(faults=(Fault(0, 1),), steps=4)),
                     (Decode, Decode(steps=2, context=64))):
        assert cls.__dataclass_params__.frozen
        d = obj.to_dict() if hasattr(obj, "to_dict") else None
        if isinstance(d, dict):
            compared = {f.name for f in dataclasses.fields(cls)
                        if f.compare}
            assert compared <= set(d), cls
    assert ClusterSpec.__dataclass_params__.frozen
