import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

Lowers + compiles every (architecture x input shape x mesh) cell against
the production meshes — (16,16)=256 chips single-pod and (2,16,16)=512
chips multi-pod — and records memory_analysis / cost_analysis / parsed
collective bytes for EXPERIMENTS.md §Dry-run and §Roofline.

    PYTHONPATH=src python -m repro.launch.dryrun \
        [--arch qwen2_1_5b] [--shape train_4k] [--multi-pod both] \
        [--out results/dryrun.csv]

The XLA_FLAGS line above MUST run before any other jax-touching import —
jax locks the device count on first init. Do not move it.
"""
import argparse
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import (SHAPES, arch_shapes, get_config, list_archs)
from repro.core import roofline
from repro.core.hw import V5E
from repro.core.modelgraph import model_flops_per_token
from repro.launch.mesh import batch_axes, make_production_mesh
from repro.models.api import build_model, input_specs
from repro.models.layers import ModelOptions
from repro.parallel import sharding
from repro.train import optimizer as optlib
from repro.train.step import (TrainConfig, make_prefill_step,
                              make_serve_step, make_train_step)


def model_options(cfg, shape, mesh, baseline: bool = False,
                  mapping: str = "tp_sp") -> ModelOptions:
    """Per-cell runtime knobs (the perf hillclimb edits these)."""
    bax = batch_axes(mesh)
    act_spec = None
    qkv_spec = None
    if mapping == "fsdp_cp" and shape.kind == "train":
        # §Perf C3: no tensor parallelism — batch over (pod,data), SEQ
        # over `model` (context parallelism), weights fully sharded
        # (ZeRO-3 over data x model). Activation TP collectives vanish;
        # per-layer weight all-gathers replace them (cheaper when
        # act_bytes/layer >> weight_bytes/layer).
        act_spec = P(bax, "model", None)
        qkv_spec = P(bax, "model", None, None)
        return ModelOptions(dtype=jnp.bfloat16, attn_impl="auto",
                            remat=True, act_spec=act_spec,
                            qkv_spec=qkv_spec, kv_spec=qkv_spec)
    if shape.kind == "train" and not baseline:
        # Megatron-SP: shard the residual stream's sequence dim over
        # `model` between layers (activation memory / 16)
        if shape.seq_len % mesh.shape["model"] == 0:
            act_spec = P(bax, "model", None)
        # attention computes with heads over `model` (SP gather at qkv)
        qkv_spec = P(bax, None, "model", None)
    elif shape.kind == "prefill" and not baseline:
        # serving: pin batch over data + heads over model; without this,
        # FSDP-sharded weights make XLA replicate activations over
        # `data` (measured 6.5x FLOPs — EXPERIMENTS.md §Perf A)
        act_spec = P(bax, None, None)
        qkv_spec = P(bax, None, "model", None)
    kv_spec = qkv_spec
    if (qkv_spec is not None and cfg.n_kv_heads
            and cfg.n_kv_heads % mesh.shape["model"]):
        kv_spec = P(bax, None, None, None)   # KV heads replicated in TP
    # explicit expert parallelism (§Perf B): all-to-all dispatch instead
    # of GSPMD's all-gather/all-reduce of the full token buffer
    moe_impl, ep_axis, dp_axes = "gather", None, None
    if (cfg.moe is not None and not baseline
            and shape.kind in ("train", "prefill")
            and cfg.moe.n_experts % mesh.shape["model"] == 0):
        moe_impl, ep_axis, dp_axes = "ep_a2a", "model", bax
    # flash block autotune (§Perf C5): keep the per-step score tile
    # (B_loc, H_loc, bq, bkv) f32 inside VMEM so it never spills to HBM
    block_q, block_kv = 512, 1024
    if cfg.n_heads and not baseline:
        import numpy as np
        dp_shards = int(np.prod([mesh.shape[a] for a in bax]))
        b_loc = max(1, shape.global_batch // dp_shards)
        h_loc = max(1, cfg.n_heads // mesh.shape["model"])
        budget = 96 * 2 ** 20 / 4 / b_loc / h_loc     # f32 elems for bq*bkv
        while block_q * block_kv > budget and block_q > 128:
            block_q //= 2
            if block_q * block_kv > budget and block_kv > 256:
                block_kv //= 2
    return ModelOptions(dtype=jnp.bfloat16, attn_impl="auto",
                        remat=(shape.kind == "train"), act_spec=act_spec,
                        qkv_spec=qkv_spec, kv_spec=kv_spec,
                        moe_impl=moe_impl, ep_axis=ep_axis,
                        dp_axes=dp_axes, block_q=block_q,
                        block_kv=block_kv)


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               baseline: bool = False, mapping: str = "tp_sp"):
    """Lower + compile one cell; returns (report, memory_analysis_str)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    n_chips = mesh.devices.size
    opts = model_options(cfg, shape, mesh, baseline, mapping)
    bax = batch_axes(mesh)

    pshapes = jax.eval_shape(lambda: build_model(cfg, opts).init(
        jax.random.PRNGKey(0)))
    # FSDP (ZeRO-3): shard params over `data` too — beyond-paper default.
    # Serving uses it only when TP-sharded weights alone exceed ~HBM/2
    # (123B-class models); small models keep weights TP-only for latency.
    fsdp = None
    model_axis = "model"
    if mapping == "fsdp_cp" and shape.kind == "train":
        fsdp = ("data", "model")     # ZeRO-3 over the full 256 chips
        model_axis = "__no_tp__"     # disable tensor-parallel rules
    elif not baseline:
        if shape.kind == "train":
            fsdp = "data"
        elif cfg.n_params() * 2 / mesh.shape["model"] > 6e9:
            fsdp = "data"
    pspecs = sharding.param_specs(pshapes, mesh, model_axis=model_axis,
                                  fsdp_axes=fsdp)

    with jax.set_mesh(mesh):
        if shape.kind == "train":
            tstep = make_train_step(cfg, opts, TrainConfig(),
                                    grad_specs=pspecs)
            ostate = jax.eval_shape(optlib.init, pshapes)
            ospecs = optlib.state_specs(pspecs)
            if not baseline:      # ZeRO-1: moments sharded over `data` too
                ospecs = sharding.zero1_specs(ostate, ospecs, mesh)
            batch = input_specs(cfg, shape, opts)
            bspecs = sharding.batch_specs(batch, mesh, bax)
            lowered = jax.jit(
                tstep,
                in_shardings=(pspecs, ospecs, bspecs),
                out_shardings=(pspecs, ospecs, None),
                donate_argnums=(0, 1),
            ).lower(pshapes, ostate, batch)
        elif shape.kind == "prefill":
            fstep = make_prefill_step(cfg, opts)
            batch = input_specs(cfg, shape, opts)
            bspecs = sharding.batch_specs(batch, mesh, bax)
            lowered = jax.jit(
                fstep, in_shardings=(pspecs, bspecs),
            ).lower(pshapes, batch)
        else:  # decode
            sstep = make_serve_step(cfg, opts)
            specs = input_specs(cfg, shape, opts)
            cache, batch = specs["cache"], specs["batch"]
            cspecs = sharding.cache_specs(
                cache, mesh, bax, seq_axis="data")
            bspecs = sharding.batch_specs(batch, mesh, bax)
            lowered = jax.jit(
                sstep,
                in_shardings=(pspecs, cspecs, bspecs),
                out_shardings=(None, cspecs),
                donate_argnums=(1,),
            ).lower(pshapes, cache, batch)

        compiled = lowered.compile()

    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()

    tokens = shape.global_batch * shape.seq_len
    if shape.is_decode:
        tokens = shape.global_batch          # one new token per sequence
    mf = model_flops_per_token(cfg) * tokens
    if shape.kind == "train":
        pass                                  # 6ND already includes bwd
    else:
        mf /= 3.0                             # fwd only = 2ND

    rep = roofline.analyze(arch, shape_name, mesh_name, n_chips, cost, hlo,
                           mf, V5E, mem)
    return rep, mem


def run(archs, shapes, pods, out=None, baseline=False, verbose=True,
        mapping="tp_sp"):
    rows = [roofline.HEADER]
    failures = []
    for arch in archs:
        cfg = get_config(arch)
        valid = {s.name for s in arch_shapes(cfg)}
        for shape_name in shapes:
            if shape_name not in valid:
                continue
            for multi_pod in pods:
                tag = f"{arch}/{shape_name}/{'2x16x16' if multi_pod else '16x16'}"
                t0 = time.time()
                try:
                    rep, mem = lower_cell(arch, shape_name, multi_pod,
                                          baseline, mapping)
                    rows.append(rep.row())
                    if verbose:
                        print(f"[ok] {tag}: compile {time.time()-t0:.1f}s "
                              f"dominant={rep.dominant} "
                              f"t=({rep.t_compute*1e3:.2f},"
                              f"{rep.t_memory*1e3:.2f},"
                              f"{rep.t_collective*1e3:.2f})ms "
                              f"frac={rep.roofline_fraction:.2f}")
                        print(f"     memory: {mem}")
                except Exception as e:
                    failures.append((tag, repr(e)))
                    print(f"[FAIL] {tag}: {e}")
                    if verbose:
                        traceback.print_exc()
    if out:
        with open(out, "w") as f:
            f.write("\n".join(rows) + "\n")
        print(f"wrote {out}")
    return rows, failures


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", default="both",
                    choices=["both", "single", "multi"])
    ap.add_argument("--baseline", action="store_true",
                    help="paper-faithful baseline (no beyond-paper opts)")
    ap.add_argument("--mapping", default="tp_sp",
                    choices=["tp_sp", "fsdp_cp"],
                    help="parallelism mapping (fsdp_cp = §Perf C3)")
    ap.add_argument("--out", default=None)
    ap.add_argument("-q", "--quiet", action="store_true")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(list_archs(assigned_only=True))
    shapes = [args.shape] if args.shape else list(SHAPES)
    pods = {"both": [False, True], "single": [False],
            "multi": [True]}[args.multi_pod]
    _, failures = run(archs, shapes, pods, args.out, args.baseline,
                      verbose=not args.quiet, mapping=args.mapping)
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for tag, err in failures:
            print(f"  {tag}: {err}")
        sys.exit(1)
    print("\nall cells compiled OK")


if __name__ == "__main__":
    main()
