"""Property-based tests (hypothesis) for ``partition_stages`` over
NON-UNIFORM layer lists — the generalization the scenario axis rests
on: serving graphs (embed + heterogeneous blocks + head, KV-read
blocks of varying weight) must partition soundly for every pp.

Invariants checked for arbitrary positive-FLOPs layer lists:
every layer appears exactly once, order is preserved, ``balanced=True``
yields no empty stage whenever ``len(layers) >= pp``, and the heaviest
balanced stage is within one-max-layer of the ideal per-stage load.
"""
import pytest

try:
    import hypothesis as hp
    import hypothesis.strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # optional dependency; spot-checks still run
    HAVE_HYPOTHESIS = False

from repro.core.events import partition_stages
from repro.core.modelgraph import GEMM, LayerSpec


def _layer(i: int, flops_units: int) -> LayerSpec:
    # fwd_flops == 2 * flops_units (GEMM flops = 2*m*n*k)
    return LayerSpec(name=f"l{i}", kind="attn_ffn", count=1,
                     gemms=(GEMM(flops_units, 1, 1),),
                     shard_axes=("n",), param_bytes=1.0, act_bytes=1.0)


def _layers(units):
    return [_layer(i, u) for i, u in enumerate(units)]


if HAVE_HYPOTHESIS:
    LAYER_LISTS = st.lists(st.integers(min_value=1, max_value=10**6),
                           min_size=1, max_size=48)
    PP = st.integers(min_value=1, max_value=8)


    @hp.given(units=LAYER_LISTS, pp=PP, balanced=st.booleans())
    @hp.settings(max_examples=120, deadline=None)
    def test_every_layer_exactly_once_in_order(units, pp, balanced):
        layers = _layers(units)
        stages = partition_stages(layers, pp, balanced=balanced)
        assert len(stages) == pp
        assert [s.index for s in stages] == list(range(pp))
        flat = [l for s in stages for l in s.layers]
        assert [l.name for l in flat] == [l.name for l in layers]


    @hp.given(units=LAYER_LISTS, pp=PP)
    @hp.settings(max_examples=120, deadline=None)
    def test_balanced_has_no_empty_stage(units, pp):
        hp.assume(len(units) >= pp)
        stages = partition_stages(_layers(units), pp, balanced=True)
        assert all(s.layers for s in stages)


    @hp.given(units=LAYER_LISTS, pp=PP)
    @hp.settings(max_examples=120, deadline=None)
    def test_balanced_flops_within_bound(units, pp):
        """Greedy prefix split bound: no stage exceeds the ideal load by
        more than the single heaviest layer (each stage closes at the first
        layer that reaches the running target)."""
        hp.assume(len(units) >= pp)
        layers = _layers(units)
        stages = partition_stages(layers, pp, balanced=True)
        total = sum(l.fwd_flops for l in layers)
        heaviest = max(l.fwd_flops for l in layers)
        for s in stages:
            load = sum(l.fwd_flops for l in s.layers)
            assert load <= total / pp + heaviest + 1e-9


    @hp.given(units=LAYER_LISTS, pp=PP)
    @hp.settings(max_examples=60, deadline=None)
    def test_default_pads_trailing_empty_stages_only(units, pp):
        """The historic default may pad empty stages, but only at the TAIL
        (training goldens bake this in) — never an empty stage followed by
        a non-empty one."""
        stages = partition_stages(_layers(units), pp, balanced=False)
        seen_empty = False
        for s in stages:
            if not s.layers:
                seen_empty = True
            else:
                assert not seen_empty



# deterministic spot-checks so the invariants are exercised even where
# hypothesis is not installed (it is an optional dependency)
@pytest.mark.parametrize("units,pp", [
    ([1], 4), ([1, 1, 1, 1], 4), ([100, 1, 1, 1], 4),
    ([1, 1, 1, 100], 2), ([5, 4, 3, 2, 1, 1, 1, 1], 3),
])
def test_partition_spot_checks(units, pp):
    layers = _layers(units)
    stages = partition_stages(layers, pp, balanced=True)
    assert len(stages) == pp
    flat = [l.name for s in stages for l in s.layers]
    assert flat == [l.name for l in layers]
    if len(units) >= pp:
        assert all(s.layers for s in stages)
