"""mamba2-2.7b [ssm] — attention-free, SSD (state-space duality).

64L d_model=2560 (attn-free) d_ff=0 vocab=50280, ssm_state=128
[arXiv:2405.21060; unverified]

long_500k INCLUDED: O(1)-state decode. Decode shapes use the recurrent SSD
step with a (B, nheads, head_dim, d_state) cache.
"""
from repro.configs.base import ArchConfig, SSMConfig, register

CONFIG = register(ArchConfig(
    name="mamba2_2_7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=0,                  # attention-free
    n_kv_heads=0,
    d_ff=0,                     # SSD block replaces the FFN
    vocab=50280,
    ssm=SSMConfig(d_state=128, head_dim=64, chunk=256, expand=2),
    shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
    source="arXiv:2405.21060; unverified",
))
