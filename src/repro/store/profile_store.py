"""Disk-backed, content-addressed profile + build store.

The paper's core economy is unique-event dedup — profile each event
ONCE, reuse it everywhere (Observation 1) — but until this module the
reuse layer lived per-process: every nightly rerun, search and executor
worker re-derived the same event means and engine builds. The
``ProfileStore`` persists both caches to disk, shared across processes,
the same shared op/profile-database architecture Proteus and DistIR
build around:

* **event times** — keyed on structural :class:`~repro.core.events.Event`
  identity (the frozen-dataclass fields minus the display-only name),
  serialized as canonical JSON and addressed by its SHA-256. Values are
  Python floats; JSON ``repr`` round-trips them EXACTLY, so a
  store-served sweep is bit-identical to a cold in-process run.
* **engine builds** — :class:`~repro.core.engine.EngineBuild` pickles
  keyed on the existing BuildCache tuple
  ``(cfg, schedule-stripped strategy, microbatch, seq)``, addressed by
  the SHA-256 of the tuple's canonical JSON.

Both namespaces are scoped per (provider class, cluster spec): an
``AnalyticalProvider`` on ``a40-cluster`` never serves times measured
by a ``MeasuredProvider`` or profiled for ``v5e-pod``.

Invalidation follows the in-process rule: every entry records the
provider's ``cache_version`` at write time and is served only when it
matches the reading provider's current version — a ``clear_cache()``
(version bump) makes all older persisted entries stale, exactly as it
invalidates in-process engines. Corrupted files (truncated JSON, bad
pickles, key mismatches) are rejected and counted, never served.

Writes are atomic (``os.replace`` of a same-directory temp file) and
idempotent (content-addressed names), so concurrent executor workers
and nightly reruns share one store safely: two writers producing the
same content race onto the same bytes, different content lands in
different files, and readers merge shards by set-union.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import pickle
import tempfile
from typing import Dict, Optional, Tuple

from repro.core.engine import EngineBuild
from repro.core.events import Event
from repro.core.modelgraph import GEMM
from repro.core.profiler import Provider

#: bump on any incompatible change to the on-disk layout; mismatched
#: entries are rejected (treated as absent), never mis-parsed.
FORMAT_VERSION = 1

_HASH_LEN = 24      # hex chars of sha256 kept in filenames


# --------------------------------------------------------------------------
# stable serialization (events, keys)
# --------------------------------------------------------------------------

def event_to_dict(e: Event) -> Dict:
    return {"kind": e.kind, "name": e.name,
            "gemms": [[g.m, g.n, g.k] for g in e.gemms],
            "coll_op": e.coll_op, "nbytes": e.nbytes,
            "n_dev": e.n_dev, "scope": e.scope}


def event_from_dict(d: Dict) -> Event:
    return Event(kind=d["kind"], name=d.get("name", ""),
                 gemms=tuple(GEMM(int(m), int(n), int(k))
                             for m, n, k in d["gemms"]),
                 coll_op=d["coll_op"], nbytes=d["nbytes"],
                 n_dev=int(d["n_dev"]), scope=d["scope"])


def _sha(payload: str) -> str:
    return hashlib.sha256(payload.encode()).hexdigest()[:_HASH_LEN]


def _canon(obj) -> str:
    """Canonical JSON — the hashing input for every content address.
    Python float repr is shortest-round-trip, so equal floats hash
    equally and distinct floats never collide by formatting."""
    return json.dumps(obj, sort_keys=True, separators=(",", ":"))


def event_key(e: Event) -> str:
    """Stable serialized key of an event's STRUCTURAL identity — the
    frozen-dataclass hash made process-independent (``name`` is
    display-only and excluded, matching ``Event.__eq__``)."""
    d = event_to_dict(e)
    d.pop("name")
    return _sha(_canon(d))


def build_key_json(key: Tuple) -> str:
    """Canonical JSON of a BuildCache build key
    ``(cfg, stripped strategy, microbatch, seq[, scenario])`` —
    dataclasses are lowered with ``asdict`` so the address is content,
    not object identity. The scenario entry is OMITTED for the train
    scenario (and legacy 4-tuples), so every pre-scenario store address
    keeps serving warm training builds unchanged."""
    if len(key) == 4:
        cfg, strat, microbatch, seq = key
        scenario = None
    else:
        cfg, strat, microbatch, seq, scenario = key
    d = {"cfg": dataclasses.asdict(cfg),
         "strategy": dataclasses.asdict(strat),
         "microbatch": int(microbatch), "seq": int(seq)}
    if scenario is not None and not scenario.is_train:
        d["scenario"] = scenario.to_dict()
    return _canon(d)


def provider_namespace(provider: Provider) -> str:
    """Store namespace per (provider class, cluster spec): times from
    different providers/clusters are different numbers and must never
    cross-serve."""
    return _sha(_canon({"provider": type(provider).__qualname__,
                        "cluster": provider.cluster.to_dict()}))


# --------------------------------------------------------------------------
# stats
# --------------------------------------------------------------------------

@dataclasses.dataclass
class StoreStats:
    """Per-store accounting (reported by ``bench_validate --store``)."""
    events_loaded: int = 0        # merged into a provider from disk
    events_saved: int = 0         # written in fresh shards
    event_shards_read: int = 0
    builds_loaded: int = 0        # EngineBuilds served from disk
    builds_saved: int = 0
    builds_missed: int = 0        # disk lookups that found nothing
    stale_rejected: int = 0       # cache_version mismatch (events+builds)
    corrupt_rejected: int = 0     # unreadable/mismatched entries

    def to_dict(self) -> Dict[str, int]:
        return dataclasses.asdict(self)


# --------------------------------------------------------------------------
# the store
# --------------------------------------------------------------------------

class ProfileStore:
    """One directory of persisted profiles + builds.

    Layout (all filenames content-addressed, all writes atomic)::

        <path>/meta.json
        <path>/<namespace>/events/<shard-sha>.json
        <path>/<namespace>/builds/<key-sha>.pkl

    Open is cheap (one mkdir + meta stat); event shards are read on
    :meth:`load_events`, builds lazily per key.
    """

    def __init__(self, path: str):
        self.path = str(path)
        self.stats = StoreStats()
        os.makedirs(self.path, exist_ok=True)
        meta = os.path.join(self.path, "meta.json")
        if not os.path.exists(meta):
            self._atomic_write(
                meta, _canon({"format": FORMAT_VERSION,
                              "store": "repro.store"}).encode())

    # ---- low-level ----

    def _atomic_write(self, path: str, data: bytes) -> None:
        """Same-directory temp file + ``os.replace``: readers never see
        a partial file, and concurrent identical writers converge on
        identical bytes."""
        d = os.path.dirname(path)
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, prefix=".tmp-")
        try:
            with os.fdopen(fd, "wb") as f:
                f.write(data)
            os.replace(tmp, path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    def _events_dir(self, provider: Provider) -> str:
        return os.path.join(self.path, provider_namespace(provider),
                            "events")

    def _builds_dir(self, provider: Provider) -> str:
        return os.path.join(self.path, provider_namespace(provider),
                            "builds")

    # ---- event times ----

    def save_events(self, provider: Provider,
                    events: Optional[Dict[Event, float]] = None) -> int:
        """Persist ``events`` (default: the provider's full cache
        snapshot) as one content-addressed shard. Idempotent: an
        already-persisted identical shard is skipped. Returns the
        number of events written (0 on skip/empty)."""
        if events is None:
            events = provider.cache_snapshot()
        if not events:
            return 0
        rows = sorted(
            ({**event_to_dict(e), "t": t} for e, t in events.items()),
            key=lambda r: _canon(r))
        doc = {"format": FORMAT_VERSION,
               "cache_version": provider.cache_version,
               "events": rows}
        payload = _canon(doc)
        path = os.path.join(self._events_dir(provider),
                            _sha(payload) + ".json")
        if os.path.exists(path):
            return 0
        self._atomic_write(path, payload.encode())
        self.stats.events_saved += len(rows)
        return len(rows)

    def load_events(self, provider: Provider) -> int:
        """Merge every valid persisted event shard into ``provider``'s
        cache (union, incumbent wins — see ``Provider.merge_cache``).
        Shards with a stale ``cache_version`` or any corruption are
        rejected, not served. Stats (hit/miss accounting) are NOT
        touched: disk loads are neither evaluations nor hits. Returns
        how many events were new to the provider."""
        d = self._events_dir(provider)
        if not os.path.isdir(d):
            return 0
        fresh = 0
        for fn in sorted(os.listdir(d)):
            if not fn.endswith(".json"):
                continue
            try:
                with open(os.path.join(d, fn), "rb") as f:
                    doc = json.loads(f.read().decode())
                if doc["format"] != FORMAT_VERSION:
                    self.stats.corrupt_rejected += 1
                    continue
                if doc["cache_version"] != provider.cache_version:
                    self.stats.stale_rejected += 1
                    continue
                entries = {event_from_dict(r): float(r["t"])
                           for r in doc["events"]}
            except Exception:
                self.stats.corrupt_rejected += 1
                continue
            self.stats.event_shards_read += 1
            n = provider.merge_cache(entries)
            fresh += n
            self.stats.events_loaded += n
        return fresh

    # ---- engine builds ----

    def save_build(self, provider: Provider, key: Tuple,
                   build: EngineBuild) -> bool:
        """Persist one :class:`EngineBuild` under its content address.
        Skips (returns False) if a LIVE entry already exists — builds
        are deterministic per (key, cache_version), so that incumbent
        is identical. A stale-version or corrupt incumbent (unusable by
        any current reader) is overwritten, not kept."""
        kj = build_key_json(key)
        path = os.path.join(self._builds_dir(provider),
                            _sha(kj) + ".pkl")
        if os.path.exists(path):
            try:
                with open(path, "rb") as f:
                    old = pickle.load(f)
                if (old["format"] == FORMAT_VERSION
                        and old["cache_version"]
                        == provider.cache_version):
                    return False
            except Exception:
                pass
        doc = {"format": FORMAT_VERSION,
               "cache_version": provider.cache_version,
               "key": kj, "build": build}
        self._atomic_write(path, pickle.dumps(doc, protocol=4))
        self.stats.builds_saved += 1
        return True

    def load_build(self, provider: Provider,
                   key: Tuple) -> Optional[EngineBuild]:
        """Fetch the persisted build for ``key``, or None. Validates
        format, ``cache_version`` and the full key JSON (guarding
        against truncation-by-hash and corrupt pickles)."""
        kj = build_key_json(key)
        path = os.path.join(self._builds_dir(provider),
                            _sha(kj) + ".pkl")
        if not os.path.exists(path):
            self.stats.builds_missed += 1
            return None
        try:
            with open(path, "rb") as f:
                doc = pickle.load(f)
            if doc["format"] != FORMAT_VERSION or doc["key"] != kj:
                self.stats.corrupt_rejected += 1
                return None
        except Exception:
            self.stats.corrupt_rejected += 1
            return None
        if doc["cache_version"] != provider.cache_version:
            self.stats.stale_rejected += 1
            return None
        build = doc["build"]
        if not isinstance(build, EngineBuild):
            self.stats.corrupt_rejected += 1
            return None
        self.stats.builds_loaded += 1
        return build

    # ---- garbage collection / compaction ----

    def gc(self, provider: Optional[Provider] = None) -> Dict[str, int]:
        """Compact the store in place.

        Per namespace: merge every LIVE event shard (format matches,
        ``cache_version`` matches the live version) into ONE
        content-addressed shard, then delete all other shards —
        including stale-version orphans left behind by
        ``clear_cache()`` bumps and corrupt/truncated files. Build
        pickles are validated the same way; stale or corrupt ones are
        deleted, live ones stay (they are already one file per key).

        The live version is ``provider.cache_version`` when a provider
        is given (its namespace only); otherwise, per namespace, the
        HIGHEST version present in any valid shard or build — the most
        recent writer wins, exactly matching what a current reader
        would accept.

        Idempotent, and atomic per write: a crash mid-gc leaves only
        valid content-addressed files. Returns a stats dict.
        """
        if provider is not None:
            namespaces = [provider_namespace(provider)]
        else:
            namespaces = sorted(
                fn for fn in os.listdir(self.path)
                if os.path.isdir(os.path.join(self.path, fn)))
        out = {"namespaces": 0, "shards_before": 0, "shards_after": 0,
               "events_live": 0, "events_dropped": 0,
               "builds_kept": 0, "builds_dropped": 0}
        for ns in namespaces:
            ns_dir = os.path.join(self.path, ns)
            if not os.path.isdir(ns_dir):
                continue
            out["namespaces"] += 1
            ev_dir = os.path.join(ns_dir, "events")
            b_dir = os.path.join(ns_dir, "builds")

            # pass 1: parse everything, find the live version
            shards = []          # (filename, version, rows) for valid
            bad_shards = []
            if os.path.isdir(ev_dir):
                for fn in sorted(os.listdir(ev_dir)):
                    if not fn.endswith(".json"):
                        continue
                    out["shards_before"] += 1
                    try:
                        with open(os.path.join(ev_dir, fn), "rb") as f:
                            doc = json.loads(f.read().decode())
                        if doc["format"] != FORMAT_VERSION:
                            raise ValueError("format")
                        rows = [{**event_to_dict(event_from_dict(r)),
                                 "t": float(r["t"])}
                                for r in doc["events"]]
                        shards.append((fn, doc["cache_version"], rows))
                    except Exception:
                        bad_shards.append(fn)
            builds = []          # (filename, version) for valid
            bad_builds = []
            if os.path.isdir(b_dir):
                for fn in sorted(os.listdir(b_dir)):
                    if not fn.endswith(".pkl"):
                        continue
                    try:
                        with open(os.path.join(b_dir, fn), "rb") as f:
                            doc = pickle.load(f)
                        if (doc["format"] != FORMAT_VERSION
                                or _sha(doc["key"]) + ".pkl" != fn
                                or not isinstance(doc["build"],
                                                  EngineBuild)):
                            raise ValueError("corrupt")
                        builds.append((fn, doc["cache_version"]))
                    except Exception:
                        bad_builds.append(fn)
            if provider is not None:
                live = provider.cache_version
            else:
                versions = ([v for _, v, _ in shards]
                            + [v for _, v in builds])
                live = max(versions, default=0)

            # pass 2: rewrite live events as one shard (union,
            # first-sorted-shard incumbent wins — the merge_cache rule)
            merged: Dict[str, Dict] = {}
            for _, v, rows in shards:
                if v != live:
                    continue
                for r in rows:
                    k = _canon({k2: v2 for k2, v2 in r.items()
                                if k2 not in ("name", "t")})
                    merged.setdefault(k, r)
            keep = None
            if merged:
                rows = sorted(merged.values(), key=lambda r: _canon(r))
                doc = {"format": FORMAT_VERSION, "cache_version": live,
                       "events": rows}
                payload = _canon(doc)
                keep = _sha(payload) + ".json"
                self._atomic_write(os.path.join(ev_dir, keep),
                                   payload.encode())
                out["shards_after"] += 1
                out["events_live"] += len(rows)
            total = sum(len(rows) for _, v, rows in shards)
            out["events_dropped"] += total - len(merged)

            # pass 3: delete everything superseded
            for fn, _, _ in shards:
                if fn != keep:
                    os.unlink(os.path.join(ev_dir, fn))
            for fn in bad_shards:
                os.unlink(os.path.join(ev_dir, fn))
            for fn, v in builds:
                if v == live:
                    out["builds_kept"] += 1
                else:
                    out["builds_dropped"] += 1
                    os.unlink(os.path.join(b_dir, fn))
            for fn in bad_builds:
                out["builds_dropped"] += 1
                os.unlink(os.path.join(b_dir, fn))
        return out

    # ---- accounting ----

    def entry_counts(self, provider: Provider) -> Dict[str, int]:
        """On-disk entry counts for the provider's namespace."""
        def count(d: str, suffix: str) -> int:
            if not os.path.isdir(d):
                return 0
            return sum(1 for fn in os.listdir(d)
                       if fn.endswith(suffix))
        return {
            "event_shards": count(self._events_dir(provider), ".json"),
            "builds": count(self._builds_dir(provider), ".pkl"),
        }

    def snapshot(self) -> Dict[str, int]:
        return self.stats.to_dict()


def open_store(store) -> ProfileStore:
    """Coerce a path or an already-open store into a ProfileStore."""
    return store if isinstance(store, ProfileStore) \
        else ProfileStore(store)
