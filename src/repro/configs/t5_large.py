"""T5-Large — paper evaluation model (Fig. 8/9). [arXiv:1910.10683]

24L (12 enc + 12 dec modeled as n_layers=12 enc-dec pairs) d_model=1024
16H d_ff=4096 vocab=32128. Encoder-decoder.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="t5_large",
    family="dense",
    n_layers=12,               # 12 encoder + 12 decoder layers (enc_dec pairs)
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=32128,
    mlp_gelu=True,
    enc_dec=True,
    tie_embeddings=True,
    shapes=("train_4k",),
    source="arXiv:1910.10683 (paper eval model)",
))
