"""Gradient compression (distributed-optimization trick).

Int8 uniform quantization with per-leaf fp32 scale: 4x less DP
all-reduce volume. The reduction is done in int32 (no overflow for
dp <= 2^23) via an explicit shard_map psum — the pattern a production
runtime uses on the `data` axis when gradients dominate ICI/DCN traffic
(multi-pod: DCN is 4x slower than ICI, so 4x compression restores
pod-local step time; see EXPERIMENTS.md §Perf).

Error feedback (residual accumulation) keeps convergence unbiased.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x.astype(jnp.float32))) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_tree(grads: Any) -> Any:
    return jax.tree.map(quantize_int8, grads)


def compressed_psum(grads: Any, axis_name: str) -> Any:
    """Quantize → int32 psum over `axis_name` → dequantize → mean.

    Use inside shard_map over the DP axis. The psum moves int8-scale
    volume (int32 accumulate on-wire is handled by XLA as int32; real
    deployments pack to int8 with a two-phase reduce — we model the 4x
    byte reduction in DistSim's event model and verify numerics here).
    """
    n = jax.lax.psum(jnp.ones(()), axis_name)

    def one(g):
        q, scale = quantize_int8(g)
        tot = jax.lax.psum(q.astype(jnp.int32), axis_name)
        # scales differ per rank: reduce with max for a conservative bound
        smax = jax.lax.pmax(scale, axis_name)
        return (tot.astype(jnp.float32) * smax / n).astype(g.dtype)

    return jax.tree.map(one, grads)


class ErrorFeedback:
    """Residual accumulator: g_sent = Q(g + e); e ← g + e − g_sent."""

    @staticmethod
    def init(grads: Any) -> Any:
        return jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)

    @staticmethod
    def apply(grads: Any, residual: Any):
        def one(g, e):
            target = g.astype(jnp.float32) + e
            q, scale = quantize_int8(target)
            sent = dequantize_int8(q, scale)
            return sent.astype(g.dtype), target - sent
        pairs = jax.tree.map(one, grads, residual)
        sent = jax.tree.map(lambda p: p[0], pairs,
                            is_leaf=lambda x: isinstance(x, tuple))
        resid = jax.tree.map(lambda p: p[1], pairs,
                             is_leaf=lambda x: isinstance(x, tuple))
        return sent, resid
