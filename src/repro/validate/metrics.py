"""Per-cell conformance metrics (paper §5).

A :class:`CellMetrics` is one predict-vs-replay comparison reduced to
the paper's evaluation numbers: batch-time error (§5.2, target <4%),
per-device activity-time error (§5.3, target <5%), per-stage timestamp
error (§5.4), plus duration/utilization/bubble deltas that localize a
regression (schedule drift vs event-time drift). Multi-seed replays
aggregate field-wise (mean), with the worst seed's batch-time error
kept so a single bad draw can't hide in the average.

Two evaluation paths compute the same numbers:

* :func:`compare_timelines` — the naive oracle: materializes both
  ``Activity`` lists and matches compute events by ``(device, name)``;
* :func:`compare_batch` — array-native over a ``TimelineBatch`` pair:
  pred and replay share one engine, so matched pairs are simply the
  same ``(device, task index)`` slots and every metric reduces over
  stacked ``(S, dp, mp, tasks)`` arrays. Zero ``Activity`` objects.

``tests/test_validate_metrics.py`` holds the differential/property
harness pinning the two together.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence

import numpy as np

from repro.core.serde import dataclass_from_dict
from repro.core.timeline import Timeline, TimelineBatch, error_summary


@dataclasses.dataclass(frozen=True)
class CellMetrics:
    batch_time_error: float = 0.0
    activity_error_mean: float = 0.0
    activity_error_max: float = 0.0
    stage_error_mean: float = 0.0
    stage_error_max: float = 0.0
    duration_error_mean: float = 0.0
    duration_error_max: float = 0.0
    utilization_delta_max: float = 0.0
    bubble_delta: float = 0.0
    worst_batch_time_error: float = 0.0

    def to_dict(self) -> Dict[str, float]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, float]) -> "CellMetrics":
        return dataclass_from_dict(cls, d)


def compare_timelines(pred: Timeline, actual: Timeline) -> CellMetrics:
    """Metrics for one (prediction, replay) pair (naive oracle path:
    materializes and matches ``Activity`` lists)."""
    s = error_summary(pred, actual)
    return CellMetrics(worst_batch_time_error=s["batch_time_error"], **s)


def compare_batch(pred: TimelineBatch, actual: TimelineBatch
                  ) -> List[CellMetrics]:
    """Array-native metrics for every replay lane of ``actual`` against
    ``pred``, which must be a single zero-noise lane
    (``DistSim.simulate().batch``; enforced — a noisy or multi-lane
    prediction batch would silently be misread as replica-0 times).

    Both batches must come from the same engine (same task structure):
    the ``(device, name)`` activity matching of the naive path then
    degenerates to index alignment, and all paper §5 reductions run as
    NumPy ops over ``(S, dp, mp, tasks)`` stacks — no ``Activity`` is
    ever materialized. Equality with the naive path (to float
    tolerance; the reduction tree differs) is pinned by
    ``tests/test_validate_metrics.py``.
    """
    if len(pred) != 1 or pred.n_sim != 1:
        raise ValueError(
            f"compare_batch needs a single-lane zero-noise prediction "
            f"batch (simulate().batch), got S={len(pred)}, "
            f"n_sim={pred.n_sim}")
    S = len(actual)
    dp, mp, pp = actual.dp, actual.mp, actual.pp
    bt_p = float(pred.batch_times[0])
    bt_a = actual.batch_times                          # (S,)
    # §5.2, with timeline.batch_time_error's degenerate-oracle
    # semantics: a zero-length oracle vs a non-trivial prediction is
    # infinite error, not perfect agreement.
    norm = np.where(bt_a > 0, bt_a, 1.0)               # old `bt or 1.0`
    bte = np.where(bt_a > 0, np.abs(bt_p - bt_a) / norm,
                   0.0 if bt_p == 0.0 else np.inf)

    act_sum = np.zeros(S)
    act_max = np.zeros(S)
    stg_sum = np.zeros(S)
    stg_max = np.zeros(S)
    dur_sum = np.zeros(S)
    dur_max = np.zeros(S)
    n_dev = 0
    n_pairs = 0
    for d in range(pp):
        sp = pred.starts[d][0, 0]                      # (n_d,)
        ep = pred.ends[d][0, 0]
        n_d = sp.shape[0]
        if n_d == 0:
            continue
        sa, ea = actual.starts[d], actual.ends[d]      # (S, n_sim, n_d)
        if actual.n_sim != dp:
            sa = np.broadcast_to(sa, (S, dp, n_d))
            ea = np.broadcast_to(ea, (S, dp, n_d))
        offs = actual.offsets[:, :, d, :, None]        # (S, dp, mp, 1)
        sa_o = sa[:, :, None, :] + offs                # (S, dp, mp, n_d)
        ea_o = ea[:, :, None, :] + offs
        nrm = norm[:, None, None, None]
        # §5.3/§5.4 timestamp error per matched compute pair
        terr = 0.5 * (np.abs(sp - sa_o) + np.abs(ep - ea_o)) / nrm
        stg_sum += terr.sum(axis=(1, 2, 3))
        stg_max = np.maximum(stg_max, terr.max(axis=(1, 2, 3)))
        n_pairs += dp * mp * n_d
        dm = terr.mean(axis=3)                         # per-device means
        act_sum += dm.sum(axis=(1, 2))
        act_max = np.maximum(act_max, dm.max(axis=(1, 2)))
        n_dev += dp * mp
        # duration error uses materialized-activity semantics:
        # a.dur == (end+off) - (start+off), offsets not quite cancelling
        derr = np.abs((ep - sp) - (ea_o - sa_o)) / nrm
        ddm = derr.mean(axis=3)
        dur_sum += ddm.sum(axis=(1, 2))
        dur_max = np.maximum(dur_max, ddm.max(axis=(1, 2)))

    act_mean = act_sum / max(1, n_dev)
    stg_mean = stg_sum / max(1, n_pairs)
    dur_mean = dur_sum / max(1, n_dev)

    util_p = (pred.busy[0] / bt_p if bt_p > 0
              else np.zeros(pred.n_devices))
    util_a = actual.utilization()                      # (S, n_devices)
    util_max = np.abs(util_p - util_a).max(axis=1)
    bubble = np.abs((1.0 - util_a.mean(axis=1))
                    - (1.0 - util_p.mean()))

    return [CellMetrics(
        batch_time_error=float(bte[s]),
        activity_error_mean=float(act_mean[s]),
        activity_error_max=float(act_max[s]),
        stage_error_mean=float(stg_mean[s]),
        stage_error_max=float(stg_max[s]),
        duration_error_mean=float(dur_mean[s]),
        duration_error_max=float(dur_max[s]),
        utilization_delta_max=float(util_max[s]),
        bubble_delta=float(bubble[s]),
        worst_batch_time_error=float(bte[s]),
    ) for s in range(S)]


def aggregate(per_seed: Sequence[CellMetrics]) -> CellMetrics:
    """Field-wise mean over seeds; ``worst_batch_time_error`` takes the
    max so the aggregate still exposes the worst single replay."""
    if not per_seed:
        return CellMetrics()
    n = len(per_seed)
    fields = [f.name for f in dataclasses.fields(CellMetrics)]
    means = {f: sum(getattr(m, f) for m in per_seed) / n for f in fields}
    means["worst_batch_time_error"] = max(m.worst_batch_time_error
                                          for m in per_seed)
    return CellMetrics(**means)
