"""BERT-exLarge — the paper's unseen 48-layer strategy-search model (§6).

48 transformer layers; other dims follow BERT-Large scaling (d_model=1024).
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="bert_exlarge",
    family="dense",
    n_layers=48,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=30522,
    qkv_bias=True,
    mlp_gelu=True,
    shapes=("train_4k",),
    source="paper §6 strategy-search model",
))
