"""Shared dataclass↔dict round-trip helpers (validation reports,
goldens, search artifacts)."""
from __future__ import annotations

import dataclasses
import typing
from typing import Any, Dict, Type, TypeVar

T = TypeVar("T")


def _field_types(cls: type) -> Dict[str, Any]:
    """Resolved (non-string) field annotations — dataclass modules use
    ``from __future__ import annotations``, so raw annotations are
    strings until resolved against the defining module's globals."""
    try:
        return typing.get_type_hints(cls)
    except Exception:           # unresolvable forward refs: no nesting
        return {}


def dataclass_from_dict(cls: Type[T], d: dict) -> T:
    """Construct ``cls`` from a dict, ignoring unknown keys — the one
    place that defines how report dicts rehydrate, so schema-migration
    behavior changes in exactly one spot.

    Dict values for fields whose annotated type is itself a dataclass
    are rehydrated recursively (``ClusterSpec.chip`` → ``ChipSpec``),
    matching what ``dataclasses.asdict`` lowers on the way out."""
    fields = {f.name for f in dataclasses.fields(cls)}
    hints = _field_types(cls)
    out: Dict[str, Any] = {}
    for k, v in d.items():
        if k not in fields:
            continue
        t = hints.get(k)
        if dataclasses.is_dataclass(t) and isinstance(v, dict):
            v = dataclass_from_dict(t, v)
        out[k] = v
    return cls(**out)
