"""AST linter for the repo's own written contracts.

Generic linters (ruff's pyflakes/bugbear gate) can't see repo-specific
invariants; these rules encode the ones that have actually bitten:

======  ============================================================
rule    contract
======  ============================================================
L001    ``Event.name`` is display-only (``compare=False`` in
        ``events.py``): no code may compare, test membership on, or
        branch on an event's ``.name`` — structural identity is the
        paper's unique-event dedup, and name-keyed logic silently
        breaks it
L002    cache-key completeness: a frozen spec dataclass that defines
        ``to_dict`` must serialize EVERY field — either via
        ``dataclasses.asdict`` or a dict whose keys cover all fields.
        A field that exists but never reaches the serde path is the
        exact bug class that once let two differing specs share one
        store address
L003    no iteration over unordered containers feeding ordered
        construction in ``repro/core`` and ``repro/store``: a bare
        ``for x in set(...)`` (or a set literal / set union) leaks
        hash order into whatever is built from it — wrap in
        ``sorted(...)``. ``dict.values()``/``.keys()`` are flagged
        only when fed straight into tuple/array constructors
L004    determinism of build/compile paths (``repro/core`` minus the
        measuring ``profiler.py``, and ``repro/store``): no wall-clock
        reads (``time.time``/``perf_counter``/``monotonic``) and no
        unseeded RNG (``np.random.<draw>``, zero-argument
        ``default_rng()``/``RandomState()``) — builds must be pure
        functions of their inputs or content addresses lie
======  ============================================================

Pure stdlib ``ast`` — no third-party parser, works on the numpy-only
CI image. Entry points: :func:`lint_paths` (files/dirs),
:func:`lint_source` (one source string — the mutation suite's hook).
"""
from __future__ import annotations

import ast
import os
from typing import List, Optional, Sequence

from repro.analyze.findings import Finding

#: variable names treated as "an Event" for L001. The rule is
#: heuristic by necessity (no type inference); these cover the repo's
#: idiom for event-typed locals and comprehension targets.
EVENT_VARS = frozenset({"e", "ev", "evt", "event"})

#: np.random draws that consume global (unseeded) RNG state.
UNSEEDED_DRAWS = frozenset({
    "rand", "randn", "random", "random_sample", "randint", "choice",
    "shuffle", "permutation", "standard_normal", "normal", "uniform",
    "seed",
})

_WALLCLOCK = frozenset({"time", "perf_counter", "monotonic",
                        "perf_counter_ns", "time_ns", "monotonic_ns"})

#: constructors whose argument order is semantically load-bearing.
_ORDERED_CTORS = frozenset({"tuple", "asarray", "array", "stack",
                            "concatenate", "fromiter"})


def _norm(path: str) -> str:
    return path.replace(os.sep, "/")


def _in_core_or_store(path: str) -> bool:
    p = _norm(path)
    return "repro/core/" in p or "repro/store/" in p


def _is_build_path(path: str) -> bool:
    """L004 scope: build/compile paths — core + store, except the
    profiler (whose entire job is reading real clocks)."""
    p = _norm(path)
    if p.endswith("repro/core/profiler.py"):
        return False
    return _in_core_or_store(p)


def _attr_chain(node: ast.AST) -> Optional[str]:
    """'a.b.c' for nested Name/Attribute nodes, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _is_event_name_attr(node: ast.AST) -> bool:
    """``<event>.name`` where <event> is an event-typed expression."""
    if not (isinstance(node, ast.Attribute) and node.attr == "name"):
        return False
    val = node.value
    if isinstance(val, ast.Name) and val.id.lower() in EVENT_VARS:
        return True
    if isinstance(val, ast.Call):
        fn = _attr_chain(val.func)
        return fn is not None and fn.split(".")[-1] == "Event"
    return False


def _is_set_expr(node: ast.AST) -> bool:
    """Expressions whose iteration order is hash-dependent."""
    if isinstance(node, ast.Set) or isinstance(node, ast.SetComp):
        return True
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
            and node.func.id in ("set", "frozenset"):
        return True
    if isinstance(node, ast.BinOp) and isinstance(
            node.op, (ast.BitOr, ast.BitAnd, ast.Sub, ast.BitXor)):
        return _is_set_expr(node.left) or _is_set_expr(node.right)
    return False


def _is_dict_view(node: ast.AST) -> bool:
    return (isinstance(node, ast.Call) and not node.args
            and isinstance(node.func, ast.Attribute)
            and node.func.attr in ("values", "keys"))


class _Linter(ast.NodeVisitor):
    def __init__(self, path: str):
        self.path = path
        self.findings: List[Finding] = []
        self._core_store = _in_core_or_store(path)
        self._build_path = _is_build_path(path)

    def _add(self, rule: str, node: ast.AST, message: str) -> None:
        line = getattr(node, "lineno", 0)
        self.findings.append(Finding(
            rule=rule, message=message,
            where=f"{self.path}:{line}"))

    # ---- L001: Event.name is display-only ----

    def visit_Compare(self, node: ast.Compare) -> None:
        for operand in [node.left, *node.comparators]:
            if _is_event_name_attr(operand):
                self._add("L001", node,
                          "comparison on Event.name — name is "
                          "display-only (compare=False); key on the "
                          "structural fields (kind/op/shape/scope)")
                break
        self.generic_visit(node)

    def visit_If(self, node: ast.If) -> None:
        if _is_event_name_attr(node.test):
            self._add("L001", node,
                      "branch on Event.name — name is display-only")
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call) -> None:
        # L001: e.name.startswith(...) / endswith(...) — comparisons
        # in method form
        if isinstance(node.func, ast.Attribute) \
                and node.func.attr in ("startswith", "endswith") \
                and _is_event_name_attr(node.func.value):
            self._add("L001", node,
                      f"Event.name.{node.func.attr}() — name is "
                      f"display-only; match on structural fields")
        # L003: ordered constructor over a raw set / dict view
        if self._core_store and isinstance(node.func, (ast.Name,
                                                       ast.Attribute)):
            fn = (node.func.id if isinstance(node.func, ast.Name)
                  else node.func.attr)
            if fn in _ORDERED_CTORS and node.args:
                arg = node.args[0]
                if isinstance(arg, ast.GeneratorExp):
                    arg = arg.generators[0].iter
                if _is_set_expr(arg):
                    self._add("L003", node,
                              f"{fn}() over an unordered set "
                              f"expression — wrap in sorted(...)")
                elif _is_dict_view(arg):
                    self._add("L003", node,
                              f"{fn}() over a dict view — iteration "
                              f"order is insertion order, not a "
                              f"stable key order; wrap in sorted(...)")
        # L004: wall-clock / unseeded RNG in build paths
        if self._build_path:
            self._check_determinism(node)
        self.generic_visit(node)

    def _check_determinism(self, node: ast.Call) -> None:
        chain = _attr_chain(node.func)
        if chain is None:
            return
        parts = chain.split(".")
        if parts[0] == "time" and len(parts) == 2 \
                and parts[1] in _WALLCLOCK:
            self._add("L004", node,
                      f"{chain}() in a build/compile path — builds "
                      f"must be pure functions of their inputs")
            return
        if len(parts) >= 2 and parts[-2] == "random" \
                and parts[0] in ("np", "numpy", "random"):
            fn = parts[-1]
            if fn in UNSEEDED_DRAWS:
                self._add("L004", node,
                          f"{chain}() draws from global RNG state in "
                          f"a build/compile path")
            elif fn in ("default_rng", "RandomState") and not node.args:
                self._add("L004", node,
                          f"{chain}() without a seed in a "
                          f"build/compile path")

    # ---- L003: bare iteration over sets ----

    def _check_iter(self, it: ast.AST) -> None:
        if self._core_store and _is_set_expr(it):
            self._add("L003", it,
                      "iteration over an unordered set expression — "
                      "wrap in sorted(...) so downstream construction "
                      "is deterministic")

    def visit_For(self, node: ast.For) -> None:
        self._check_iter(node.iter)
        self.generic_visit(node)

    def _visit_comp(self, node) -> None:
        for gen in node.generators:
            self._check_iter(gen.iter)
        self.generic_visit(node)

    visit_ListComp = _visit_comp
    visit_DictComp = _visit_comp
    visit_GeneratorExp = _visit_comp

    def visit_SetComp(self, node: ast.SetComp) -> None:
        # building a set FROM a set is order-free by construction
        self.generic_visit(node)

    # ---- L002: cache-key completeness ----

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        if _is_frozen_dataclass(node):
            self._check_spec_class(node)
        self.generic_visit(node)

    def _check_spec_class(self, node: ast.ClassDef) -> None:
        fields = []
        for stmt in node.body:
            if isinstance(stmt, ast.AnnAssign) \
                    and isinstance(stmt.target, ast.Name) \
                    and not stmt.target.id.startswith("_") \
                    and not _is_classvar(stmt.annotation):
                fields.append(stmt.target.id)
        to_dict = next(
            (s for s in node.body
             if isinstance(s, ast.FunctionDef) and s.name == "to_dict"),
            None)
        if to_dict is None or not fields:
            return
        uses_asdict = any(
            isinstance(n, ast.Call)
            and (_attr_chain(n.func) or "").split(".")[-1] == "asdict"
            for n in ast.walk(to_dict))
        if uses_asdict:
            return          # asdict covers every field by construction
        keys = set()
        literal_seen = False
        for n in ast.walk(to_dict):
            if isinstance(n, ast.Dict):
                literal_seen = True
                for k in n.keys:
                    if isinstance(k, ast.Constant) \
                            and isinstance(k.value, str):
                        keys.add(k.value)
            elif isinstance(n, ast.Subscript) \
                    and isinstance(n.slice, ast.Constant) \
                    and isinstance(n.slice.value, str):
                keys.add(n.slice.value)
        if not literal_seen:
            return          # built some other way — out of scope
        missing = sorted(set(fields) - keys)
        if missing:
            self._add("L002", to_dict,
                      f"{node.name}.to_dict() omits field(s) "
                      f"{missing} — every compared field of a frozen "
                      f"spec must reach the serde/cache-key path")


def _is_frozen_dataclass(node: ast.ClassDef) -> bool:
    for dec in node.decorator_list:
        if not isinstance(dec, ast.Call):
            continue
        fn = _attr_chain(dec.func) or ""
        if fn.split(".")[-1] != "dataclass":
            continue
        for kw in dec.keywords:
            if kw.arg == "frozen" and isinstance(kw.value, ast.Constant) \
                    and kw.value.value is True:
                return True
    return False


def _is_classvar(annotation: ast.AST) -> bool:
    if isinstance(annotation, ast.Subscript):
        annotation = annotation.value
    chain = _attr_chain(annotation) or ""
    return chain.split(".")[-1] == "ClassVar"


# --------------------------------------------------------------------------
# entry points
# --------------------------------------------------------------------------

def lint_source(source: str, path: str = "<string>") -> List[Finding]:
    """Lint one source string as if it lived at ``path`` (the path
    decides L003/L004 scoping)."""
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as exc:
        return [Finding(rule="L000", message=f"syntax error: {exc.msg}",
                        where=f"{path}:{exc.lineno or 0}")]
    linter = _Linter(path)
    linter.visit(tree)
    return sorted(linter.findings, key=lambda f: f.where)


def lint_file(path: str) -> List[Finding]:
    with open(path, encoding="utf-8") as fh:
        return lint_source(fh.read(), path)


def lint_paths(paths: Sequence[str]) -> List[Finding]:
    """Lint files and/or directory trees (``.py`` files, recursively,
    skipping ``__pycache__``)."""
    findings: List[Finding] = []
    for root in paths:
        if os.path.isfile(root):
            findings.extend(lint_file(root))
            continue
        for dirpath, dirnames, filenames in os.walk(root):
            dirnames[:] = sorted(d for d in dirnames
                                 if d != "__pycache__")
            for fn in sorted(filenames):
                if fn.endswith(".py"):
                    findings.extend(lint_file(os.path.join(dirpath, fn)))
    return findings
