"""DistSim top-level API (paper Fig. 6).

    sim = DistSim(cfg, strategy, global_batch=16, seq=512)
    pred = sim.simulate()                 # the model: zero-noise predict
    reps = sim.simulate(seeds=(0, 1, 2))  # discrete-event replay oracle

One entry point: :meth:`DistSim.simulate` returns a uniform
:class:`SimBatch` — the predict lane when ``seeds is None`` (the
paper's construction: each unique event's profiled mean used once), a
batched replay when seeds are given (every per-device event instance
with profiling jitter, straggler and clock effects — our stand-in for
the real 16-GPU cluster, see DESIGN.md §2). The historical five-method
surface (``predict``/``replay``/``predict_batched``/``replay_batched``/
``predict_and_replay``) remains as thin deprecated wrappers.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Dict, List, Optional, Sequence, Union

import numpy as np

from repro.configs.base import ArchConfig
from repro.core.costmodel import V5E_POD
from repro.core.engine import EventFlowEngine
from repro.core.events import (Stage, Strategy, build_stage_events,
                               stage_signature, unique_events)
from repro.core.hierarchy import build_positions
from repro.core.profiler import (AnalyticalProvider, Provider,
                                 profile_events, profiling_cost)
from repro.core.scenario import TRAIN, Scenario
from repro.core.timeline import Timeline, TimelineBatch


@dataclasses.dataclass
class SimResult:
    timeline: Timeline
    batch_time: float
    throughput_iters: float
    throughput_tokens: float
    utilization: Dict[int, float]
    bubble_fraction: float


def _to_result(tl: Timeline, global_batch: int, seq: int,
               scenario: Scenario = TRAIN) -> SimResult:
    bt = tl.batch_time
    util = tl.utilization()
    return SimResult(
        timeline=tl,
        batch_time=bt,
        throughput_iters=1.0 / bt if bt else 0.0,
        throughput_tokens=(scenario.tokens(global_batch, seq) / bt
                           if bt else 0),
        utilization=util,
        bubble_fraction=tl.bubble_fraction(util),
    )


class SimBatch:
    """Uniform result of :meth:`DistSim.simulate`.

    Wraps the engine's array-native :class:`TimelineBatch` (one lane
    per seed; a single zero-noise lane for predict) plus the sim's
    workload scalars, so both modes expose the same accessors:

    * arrays across lanes: :attr:`batch_times`,
      :meth:`throughput_iters`, :meth:`bubble_fraction`,
      :meth:`utilization`;
    * per-lane views: :meth:`timeline`, :meth:`result`,
      :meth:`results` (lazy — no ``Activity`` list is built until a
      timeline is inspected);
    * scalar convenience for the single-lane case:
      :attr:`batch_time` (raises on multi-seed batches rather than
      silently picking a lane).
    """

    def __init__(self, batch: TimelineBatch, global_batch: int, seq: int,
                 mode: str, scenario: Scenario = TRAIN):
        self.batch = batch
        self.global_batch = global_batch
        self.seq = seq
        self.mode = mode                       # "predict" | "replay"
        self.scenario = scenario

    def __len__(self) -> int:
        return len(self.batch)

    def __repr__(self) -> str:
        return (f"SimBatch(mode={self.mode!r}, lanes={len(self)}, "
                f"seeds={self.seeds})")

    @property
    def seeds(self) -> List[Optional[int]]:
        return list(self.batch.seeds)

    @property
    def batch_times(self) -> np.ndarray:
        return self.batch.batch_times

    @property
    def batch_time(self) -> float:
        """The single lane's batch time; ambiguous (and an error) when
        the batch holds several seeds."""
        if len(self) != 1:
            raise ValueError(
                f"batch_time is ambiguous on a {len(self)}-lane "
                f"SimBatch; use .batch_times or .result(i)")
        return float(self.batch.batch_times[0])

    def throughput_iters(self) -> np.ndarray:
        # out= zeros: without it np.divide(..., where=) leaves the
        # masked entries as uninitialized memory, which np.where then
        # multiplies — NaN/Inf garbage could poison the 0.0 branch
        bt = self.batch.batch_times
        return np.divide(1.0, bt, out=np.zeros_like(bt), where=bt > 0)

    def throughput_tokens(self) -> np.ndarray:
        """Tokens/sec per lane — scenario-aware numerator (train and
        prefill push ``global_batch * seq`` tokens per iteration;
        decode produces one token per slot per autoregressive step)."""
        return (self.throughput_iters()
                * self.scenario.tokens(self.global_batch, self.seq))

    def utilization(self) -> np.ndarray:
        """(lanes, n_devices) busy fractions."""
        return self.batch.utilization()

    def bubble_fraction(self) -> np.ndarray:
        return self.batch.bubble_fraction()

    def timeline(self, i: int = 0) -> Timeline:
        return self.batch.timeline(i)

    def result(self, i: int = 0) -> SimResult:
        """Lane ``i`` as the classic :class:`SimResult`."""
        return _to_result(self.batch.timeline(i), self.global_batch,
                          self.seq, self.scenario)

    def results(self) -> List[SimResult]:
        return [self.result(i) for i in range(len(self))]


def _deprecated(old: str, new: str) -> None:
    warnings.warn(
        f"DistSim.{old}() is deprecated; use DistSim.{new}",
        DeprecationWarning, stacklevel=3)


class DistSim:
    def __init__(self, cfg: ArchConfig, strategy: Strategy,
                 global_batch: int, seq: int,
                 provider: Optional[Provider] = None,
                 scenario: Scenario = TRAIN):
        self.cfg = cfg
        self.strategy = strategy
        self.global_batch = global_batch
        self.seq = seq
        self.provider = provider or AnalyticalProvider(V5E_POD)
        self.scenario = scenario
        # one cached engine per scenario actually simulated, plus one
        # slot for caller-provided positions
        self._engines: Dict[Scenario, EventFlowEngine] = {}
        self._engine: Optional[EventFlowEngine] = None
        self._engine_key = None
        if scenario.kind == "decode":
            if global_batch % strategy.dp:
                raise ValueError(
                    f"global_batch {global_batch} (decode slots) not "
                    f"divisible by dp = {strategy.dp}")
        elif global_batch % (strategy.dp * strategy.microbatches):
            raise ValueError(
                f"global_batch {global_batch} not divisible by "
                f"dp*microbatches = {strategy.dp * strategy.microbatches}")

    # ---- the one simulation surface ----
    def simulate(self, seeds: Union[int, Sequence[int], None] = None,
                 jitter_sigma: float = 0.025,
                 straggler_sigma: float = 0.0,
                 clock_sigma: float = 0.0,
                 positions: Optional[List[Stage]] = None,
                 scenario: Optional[Scenario] = None,
                 perturb=None):
        """Run the model once, uniformly.

        ``seeds=None`` (default) is the performance model: one
        zero-noise predict lane (the sigma arguments are ignored —
        predict is deterministic by construction). An int or sequence
        of ints replays the discrete-event oracle once per seed, all
        lanes evaluated in one vectorized pass, bit-identical per seed
        to the historical sequential ``replay(seed=s)`` calls.

        ``scenario`` overrides the sim's constructor scenario for this
        call (e.g. ``sim.simulate(scenario=Decode(steps=16))`` on a sim
        built for training).

        ``perturb`` (a :class:`repro.core.perturb.Perturbation`)
        models a degraded fleet — straggler slowdowns and injected
        failures with checkpoint-restore recovery — and returns a
        :class:`repro.core.perturb.DegradedRun` (a multi-step spliced
        timeline) instead of a single-step :class:`SimBatch`.
        ``perturb=None`` is the byte-identical unperturbed path.
        """
        if perturb is not None:
            if scenario is not None or positions is not None:
                raise ValueError(
                    "perturb composes a multi-step run over the sim's "
                    "own scenario/positions; per-call overrides are "
                    "not supported together")
            from repro.core.perturb import simulate_degraded
            return simulate_degraded(
                self, perturb, seeds=seeds, jitter_sigma=jitter_sigma,
                straggler_sigma=straggler_sigma, clock_sigma=clock_sigma)
        sc = self.scenario if scenario is None else scenario
        engine = self.engine(positions, scenario=sc)
        if seeds is None:
            return SimBatch(engine.run_batched(None), self.global_batch,
                            self.seq, "predict", sc)
        if isinstance(seeds, (int, np.integer)):
            seeds = [int(seeds)]
        batch = engine.run_batched(
            list(seeds), jitter_sigma=jitter_sigma,
            straggler_sigma=straggler_sigma, clock_sigma=clock_sigma)
        return SimBatch(batch, self.global_batch, self.seq, "replay", sc)

    # ---- deprecated 5-method surface (thin delegating wrappers) ----
    def predict(self, positions: Optional[List[Stage]] = None) -> SimResult:
        """Deprecated: use ``simulate(positions=...).result()``."""
        _deprecated("predict", "simulate(positions=...).result()")
        return self.simulate(positions=positions).result()

    def replay(self, seed: int = 0, jitter_sigma: float = 0.025,
               straggler_sigma: float = 0.0,
               clock_sigma: float = 0.0,
               positions: Optional[List[Stage]] = None) -> SimResult:
        """Deprecated: use ``simulate(seeds=seed, ...).result()``."""
        _deprecated("replay", "simulate(seeds=..., ...).result()")
        return self.simulate(
            seeds=seed, jitter_sigma=jitter_sigma,
            straggler_sigma=straggler_sigma, clock_sigma=clock_sigma,
            positions=positions).result()

    def predict_batched(self, positions: Optional[List[Stage]] = None
                        ) -> TimelineBatch:
        """Deprecated: use ``simulate(positions=...).batch``."""
        _deprecated("predict_batched", "simulate(positions=...).batch")
        return self.simulate(positions=positions).batch

    def replay_batched(self, seeds, jitter_sigma: float = 0.025,
                       straggler_sigma: float = 0.0,
                       clock_sigma: float = 0.0,
                       positions: Optional[List[Stage]] = None
                       ) -> TimelineBatch:
        """Deprecated: use ``simulate(seeds=..., ...).batch``."""
        _deprecated("replay_batched", "simulate(seeds=..., ...).batch")
        return self.simulate(
            seeds=list(seeds), jitter_sigma=jitter_sigma,
            straggler_sigma=straggler_sigma, clock_sigma=clock_sigma,
            positions=positions).batch

    def predict_and_replay(self, seeds=(0,), jitter_sigma: float = 0.025,
                           straggler_sigma: float = 0.0,
                           clock_sigma: float = 0.0, batched: bool = True):
        """Deprecated: call ``simulate()`` twice (predict lane + replay
        lanes); for the sequential differential baseline drive
        ``engine().run(seed=...)`` directly."""
        _deprecated("predict_and_replay",
                    "simulate() / simulate(seeds=...)")
        engine = self.engine()
        pred = _to_result(engine.run(), self.global_batch, self.seq)
        if batched:
            batch = engine.run_batched(list(seeds),
                                       jitter_sigma=jitter_sigma,
                                       straggler_sigma=straggler_sigma,
                                       clock_sigma=clock_sigma)
            replays = [_to_result(batch.timeline(i), self.global_batch,
                                  self.seq) for i in range(len(batch))]
        else:
            replays = [_to_result(engine.run(
                jitter_sigma=jitter_sigma,
                straggler_sigma=straggler_sigma,
                clock_sigma=clock_sigma, seed=s), self.global_batch,
                self.seq) for s in seeds]
        return pred, replays

    # ---- store-served query front-end ----
    @classmethod
    def serve(cls, store, clusters=None, **kwargs):
        """A :class:`repro.store.StrategyServer` over a warm
        :class:`repro.store.ProfileStore`: answers "(model, strategy,
        cluster) -> predicted batch time / memory headroom /
        utilization" queries at interactive latency (persisted events +
        engine builds; no re-profiling on a warm store)."""
        from repro.store.serve import StrategyServer
        return StrategyServer(store, clusters=clusters, **kwargs)

    @classmethod
    def serve_batch(cls, queries, store, clusters=None, **kwargs):
        """One-shot batch query: build a server over ``store`` and
        answer ``queries`` (a sequence of
        :class:`repro.store.ServeQuery`) via ONE mega-batch array call
        per queried cluster. Returns ``List[ServeAnswer]`` in query
        order; batch times are bit-identical to per-query
        ``simulate()``."""
        return cls.serve(store, clusters=clusters, **kwargs) \
            .answer_batch(queries)

    # ---- search-engine hooks ----
    def microbatch(self, scenario: Optional[Scenario] = None) -> int:
        sc = self.scenario if scenario is None else scenario
        return sc.microbatch_size(self.strategy, self.global_batch)

    def positions(self, scenario: Optional[Scenario] = None) -> List[Stage]:
        """Pipeline positions (pp*vpp stages) with composed fwd/bwd
        events — precompute once, pass to simulate() and the search
        pruner so candidates don't rebuild the model graph."""
        sc = self.scenario if scenario is None else scenario
        return build_positions(self.cfg, self.strategy,
                               self.microbatch(sc), self.seq,
                               self.provider.cluster, scenario=sc)

    def engine(self, positions: Optional[List[Stage]] = None,
               scenario: Optional[Scenario] = None) -> EventFlowEngine:
        """Event-flow engine for this sim. Reused across simulate()
        calls (one slot per scenario for the default positions build,
        one keyed on the caller's positions) so the per-strategy
        schedule + event-mean precomputation runs once per positions
        set.

        Explicit positions are keyed on STRUCTURAL content
        (:func:`repro.core.events.stage_signature`), not list identity:
        an equal-content list reuses the cached engine, and a
        mutated-then-reused list rebuilds instead of silently returning
        stale times. Either slot also rebuilds when the provider's
        event cache was cleared since the engine baked in its means."""
        sc = self.scenario if scenario is None else scenario
        if positions is None:
            cached = self._engines.get(sc)
            if cached is None or self._stale(cached):
                cached = EventFlowEngine(
                    self.positions(sc), self.strategy, self.provider,
                    scenario=sc)
                self._engines[sc] = cached
            return cached
        key = (sc, stage_signature(positions))
        if (self._engine is None or self._engine_key != key
                or self._stale(self._engine)):
            self._engine = EventFlowEngine(positions, self.strategy,
                                           self.provider, scenario=sc)
            self._engine_key = key
        return self._engine

    def use_engine(self, engine: EventFlowEngine) -> None:
        """Adopt a prebuilt default engine (the validate sweep's
        :class:`~repro.validate.build_cache.BuildCache` hands sims
        cached engines so per-cell simulate() skips the build). The
        engine is slotted under ITS scenario, so a serving engine and
        a training engine can both be adopted on one sim."""
        if engine.provider is not self.provider:
            raise ValueError("engine was built against a different "
                             "provider than this sim's")
        self._engines[engine.scenario] = engine

    def _stale(self, engine: EventFlowEngine) -> bool:
        return engine.cache_version != self.provider.cache_version

    def _result(self, tl: Timeline) -> SimResult:
        return _to_result(tl, self.global_batch, self.seq, self.scenario)

    # ---- Table 3 accounting ----
    def profiling_report(self) -> Dict[str, float]:
        micro = self.microbatch()     # shared floor — paths can't drift
        stages = build_stage_events(self.cfg, self.strategy, micro, self.seq,
                                    self.provider.cluster.devices_per_island)
        counts = unique_events(stages, self.strategy,
                               self.provider.cluster.devices_per_island)
        profile = profile_events(counts.keys(), self.provider)
        return profiling_cost(counts, profile)
