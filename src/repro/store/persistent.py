"""Store-backed build cache: the in-process dedup layer made durable.

``PersistentBuildCache`` extends :class:`repro.validate.BuildCache`
with a :class:`~repro.store.profile_store.ProfileStore` behind it:

* on construction, persisted event times are merged into the bound
  provider (so every subsequent ``provider.time()`` is a hit — zero
  re-profiling on a warm store);
* a build-cache miss consults the store before computing; a computed
  build is persisted immediately (atomic, content-addressed);
* :meth:`flush` writes the provider's newly-profiled events back.

Served results are bit-identical to cold in-process runs: event floats
round-trip exactly through JSON repr, builds round-trip exactly through
pickle, and the engine layer on top is byte-for-byte the same code.
"""
from __future__ import annotations

from typing import Optional, Tuple

from repro.core.engine import EngineBuild
from repro.core.profiler import Provider
from repro.store.profile_store import ProfileStore, open_store
from repro.validate.build_cache import BuildCache


class PersistentBuildCache(BuildCache):
    """A :class:`BuildCache` whose second-level storage is a
    :class:`ProfileStore` directory shared across processes."""

    def __init__(self, provider: Provider, store):
        super().__init__(provider)
        self.store: ProfileStore = open_store(store)
        self.store.load_events(provider)
        self._known = set(provider.cache_snapshot())

    # ---- BuildCache hook points ----

    def _build_fallback(self, key: Tuple) -> Optional[EngineBuild]:
        return self.store.load_build(self.provider, key)

    def _build_created(self, key: Tuple, build: EngineBuild) -> None:
        self.store.save_build(self.provider, key, build)

    # ---- event persistence ----

    def flush(self) -> int:
        """Persist events profiled since construction (or the last
        flush) as one shard. Returns the number written."""
        snap = self.provider.cache_snapshot()
        delta = {e: t for e, t in snap.items() if e not in self._known}
        n = self.store.save_events(self.provider, delta) if delta else 0
        self._known = set(snap)
        return n

    def snapshot(self) -> dict:
        out = super().snapshot()
        out["store"] = self.store.snapshot()
        return out
