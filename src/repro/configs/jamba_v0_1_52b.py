"""jamba-v0.1-52b [hybrid] — Mamba+attention 1:7 interleave, MoE 16e top-2.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536, MoE 16e top-2
[arXiv:2403.19887; hf]

long_500k INCLUDED (hybrid): attention KV caches sharded over the `data`
mesh axis (sequence parallelism); SSM layers carry O(1) state.
"""
from repro.configs.base import ArchConfig, MoEConfig, SSMConfig, register

CONFIG = register(ArchConfig(
    name="jamba_v0_1_52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=65536,
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=14336),
    moe_period=2,               # MoE on every other layer (16 of 32)
    ssm=SSMConfig(d_state=16, head_dim=64, chunk=256, expand=2),
    hybrid_period=8,            # 1 attention layer per 8 (1:7 attn:mamba)
    shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
    source="arXiv:2403.19887; hf",
))
