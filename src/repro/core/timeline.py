"""Per-device activity timeline — DistSim's output artifact (paper Fig. 6).

Activities carry (device, kind, stage, micro, start, end); utilities
compute batch time, per-device busy/idle, bubble fraction, and the
paper's evaluation metrics (batch-time error, per-device activity error,
per-stage timestamp error).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class Activity:
    device: int
    name: str              # e.g. "F:s2:m5"
    kind: str              # F | B | P2P | AR | OPT
    start: float
    end: float
    stage: int = -1
    micro: int = -1

    @property
    def dur(self) -> float:
        return self.end - self.start


@dataclasses.dataclass
class Timeline:
    activities: List[Activity]
    n_devices: int

    @property
    def batch_time(self) -> float:
        return max((a.end for a in self.activities), default=0.0)

    def by_device(self) -> Dict[int, List[Activity]]:
        out: Dict[int, List[Activity]] = {d: [] for d in range(self.n_devices)}
        for a in self.activities:
            out[a.device].append(a)
        for v in out.values():
            v.sort(key=lambda a: a.start)
        return out

    def busy_time(self, device: int, kinds=("F", "B", "AR", "OPT")) -> float:
        return sum(a.dur for a in self.activities
                   if a.device == device and a.kind in kinds)

    def utilization(self) -> Dict[int, float]:
        """Per-device busy fraction in ONE pass over the activities
        (``busy_time`` per device would be O(devices x activities) — it
        dominated 4096-device timelines). Devices with no activities —
        e.g. degenerate pp stages that got no layers and hence no OPT
        events — report 0.0, including on a fully empty timeline
        (batch_time 0)."""
        bt = self.batch_time
        if bt <= 0.0:
            return {d: 0.0 for d in range(self.n_devices)}
        busy = [0.0] * self.n_devices
        for a in self.activities:
            if a.kind in ("F", "B", "AR", "OPT"):
                busy[a.device] += a.end - a.start
        return {d: busy[d] / bt for d in range(self.n_devices)}

    def bubble_fraction(self, util: Optional[Dict[int, float]] = None
                        ) -> float:
        """Idle fraction averaged over devices; pass a precomputed
        ``utilization()`` map to avoid recomputing it."""
        if not self.activities:
            return 0.0          # nothing scheduled — no bubbles either
        if util is None:
            util = self.utilization()
        return 1.0 - sum(util.values()) / max(1, len(util))

    def compute_index(self) -> Dict[Tuple[int, str], Activity]:
        """(device, name) → activity, compute events only."""
        return {(a.device, a.name): a for a in self.activities
                if a.kind in ("F", "B")}


class LazyTimeline(Timeline):
    """Timeline whose activity list is materialized on first access.

    The event-flow engine knows the aggregate stats (batch time,
    per-device busy time) directly from its per-device arrays, so the
    O(devices x tasks) Python ``Activity`` construction is deferred
    until something actually iterates the activities (per-activity
    error metrics, trace export). ``DistSim.simulate()`` on a
    4096-device strategy never pays it.

    ``LazyTimeline.materializations`` counts every deferred build that
    actually ran, process-wide — the validate sweep's zero-
    materialization acceptance test reads it before/after a sweep.
    """

    #: process-wide count of deferred Activity-list builds that ran
    materializations: int = 0

    def __init__(self, n_devices: int, builder, batch_time: float,
                 busy: Sequence[float]):
        # deliberately does NOT call the dataclass __init__: the
        # ``activities`` field is served by the property below.
        self.n_devices = n_devices
        self._builder = builder
        self._acts: Optional[List[Activity]] = None
        self._batch_time = batch_time
        self._busy = busy                  # per-device busy seconds

    @property
    def activities(self) -> List[Activity]:
        if self._acts is None:
            LazyTimeline.materializations += 1
            self._acts = self._builder()
            self._builder = None       # release the engine state it closed over
        return self._acts

    @property
    def batch_time(self) -> float:
        return self._batch_time

    def utilization(self) -> Dict[int, float]:
        bt = self._batch_time
        if bt <= 0.0:
            return {d: 0.0 for d in range(self.n_devices)}
        return {d: self._busy[d] / bt for d in range(self.n_devices)}

    def bubble_fraction(self, util: Optional[Dict[int, float]] = None
                        ) -> float:
        # engine timelines always carry OPT activities, so the parent's
        # empty-list early-out (which would materialize) can't apply
        if util is None:
            util = self.utilization()
        return 1.0 - sum(util.values()) / max(1, len(util))


class TimelineBatch:
    """S replay runs of one engine as stacked ``(S, ...)`` arrays.

    Produced by ``EventFlowEngine.run_batched``: all seeds share a
    single dependency-resolution pass, and everything the validate
    sweep needs — per-seed batch time, per-device busy seconds, and
    the per-task compute start/end arrays that back the array-native
    error metrics — lives here as NumPy arrays. No ``Activity`` object
    is ever built unless :meth:`timeline` is called for one lane
    (trace export / debugging), which returns an ordinary
    :class:`LazyTimeline`.

    Array layout (``pp`` pipeline devices, ``dp`` replicas, ``mp``
    model-parallel ranks; ``n_sim`` is ``dp`` for noisy replays and 1
    when all replicas are provably identical):

    * ``starts[d]`` / ``ends[d]``: ``(S, n_sim, n_tasks_d)`` compute
      (F/B) task times for pipeline device ``d``, in schedule order,
      WITHOUT clock offsets (offsets are per mp rank);
    * ``offsets``: ``(S, dp, pp, mp)`` clock-skew constants;
    * ``busy``: ``(S, n_devices)`` busy seconds per full device
      (device index ``(r*pp + d)*mp + j``);
    * ``batch_times``: ``(S,)``.
    """

    def __init__(self, seeds: Sequence[Optional[int]], n_devices: int,
                 dp: int, pp: int, mp: int, n_sim: int,
                 batch_times: np.ndarray, busy: np.ndarray,
                 starts: List[np.ndarray], ends: List[np.ndarray],
                 offsets: np.ndarray,
                 lane_builder: Callable[[int], Callable[[], List[Activity]]]):
        self.seeds = list(seeds)
        self.n_devices = n_devices
        self.dp, self.pp, self.mp = dp, pp, mp
        self.n_sim = n_sim
        self.batch_times = batch_times
        self.busy = busy
        self.starts = starts
        self.ends = ends
        self.offsets = offsets
        self._lane_builder = lane_builder

    def __len__(self) -> int:
        return len(self.seeds)

    def timeline(self, i: int) -> LazyTimeline:
        """Lane ``i`` as a LazyTimeline (activities still deferred)."""
        return LazyTimeline(n_devices=self.n_devices,
                            builder=self._lane_builder(i),
                            batch_time=float(self.batch_times[i]),
                            busy=self.busy[i])

    def utilization(self) -> np.ndarray:
        """(S, n_devices) busy fraction; 0 where batch_time is 0
        (mirrors ``Timeline.utilization`` on empty timelines)."""
        bt = self.batch_times[:, None]
        return np.divide(self.busy, bt, out=np.zeros_like(self.busy),
                         where=bt > 0)

    def bubble_fraction(self) -> np.ndarray:
        """(S,) idle fraction averaged over devices."""
        return 1.0 - self.utilization().mean(axis=1)


# --------------------------------------------------------------------------
# evaluation metrics (paper §5)
# --------------------------------------------------------------------------

def batch_time_error(pred: Timeline, actual: Timeline) -> float:
    """§5.2 relative iteration-time error. A zero-length oracle against
    a non-trivial prediction (or vice versa) is infinite error, not
    perfect agreement — a degenerate replay must trip the fidelity
    gate, not sail through it."""
    at = actual.batch_time
    if at == 0.0:
        return 0.0 if pred.batch_time == 0.0 else float("inf")
    return abs(pred.batch_time - at) / at


def _compute_pairs(pred: Timeline, actual: Timeline
                   ) -> List[Tuple[Tuple[int, str], Activity, Activity]]:
    """Matched (key, predicted, actual) compute activities."""
    ai = actual.compute_index()
    return [(key, p, ai[key]) for key, p in pred.compute_index().items()
            if key in ai]


def _timestamp_errors(pairs, bt: float) -> Dict[Tuple[int, str], float]:
    return {key: 0.5 * (abs(p.start - a.start) + abs(p.end - a.end)) / bt
            for key, p, a in pairs}


def _duration_errors(pairs, bt: float) -> Dict[Tuple[int, str], float]:
    return {key: abs(p.dur - a.dur) / bt for key, p, a in pairs}


def _device_means(errs: Dict[Tuple[int, str], float]) -> Dict[int, float]:
    per_dev: Dict[int, List[float]] = {}
    for (d, _), v in errs.items():
        per_dev.setdefault(d, []).append(v)
    return {d: sum(v) / len(v) for d, v in per_dev.items()}


def activity_error(pred: Timeline, actual: Timeline) -> Dict[int, float]:
    """§5.3: per-device mean |timestamp bias| of compute events,
    normalized by actual batch time."""
    return _device_means(per_stage_error(pred, actual))


def per_stage_error(pred: Timeline, actual: Timeline
                    ) -> Dict[Tuple[int, str], float]:
    """§5.4: per (device, F/B:stage:micro) timestamp error."""
    bt = actual.batch_time or 1.0
    return _timestamp_errors(_compute_pairs(pred, actual), bt)


def activity_duration_error(pred: Timeline, actual: Timeline
                            ) -> Dict[int, float]:
    """Per-device mean |duration| error of compute events, normalized by
    actual batch time — isolates event-time misprediction from schedule
    placement drift (which `activity_error` mixes in via timestamps)."""
    bt = actual.batch_time or 1.0
    return _device_means(_duration_errors(_compute_pairs(pred, actual), bt))


def _util_delta(pu: Dict[int, float], au: Dict[int, float]
                ) -> Dict[int, float]:
    # sorted: the union's hash order must not leak into the result's
    # key order (repro.analyze lint rule L003); downstream consumers
    # reduce with max/mean, but dict order reaches reports via .items()
    return {d: abs(pu.get(d, 0.0) - au.get(d, 0.0))
            for d in sorted(set(pu) | set(au))}


def utilization_delta(pred: Timeline, actual: Timeline) -> Dict[int, float]:
    """Per-device |predicted − actual| busy fraction."""
    return _util_delta(pred.utilization(), actual.utilization())


def _mean_max(vals) -> Tuple[float, float]:
    vals = list(vals)
    if not vals:
        return 0.0, 0.0
    return sum(vals) / len(vals), max(vals)


def error_summary(pred: Timeline, actual: Timeline) -> Dict[str, float]:
    """All paper §5 conformance metrics for one predict-vs-replay pair,
    as a flat dict — the per-cell payload of ``repro.validate``. The
    compute-activity match and the utilization maps are each built once
    and shared across the derived metrics."""
    bt = actual.batch_time or 1.0
    pairs = _compute_pairs(pred, actual)
    stage = _timestamp_errors(pairs, bt)
    act_mean, act_max = _mean_max(_device_means(stage).values())
    stg_mean, stg_max = _mean_max(stage.values())
    dur_mean, dur_max = _mean_max(
        _device_means(_duration_errors(pairs, bt)).values())
    pu, au = pred.utilization(), actual.utilization()
    _, util_max = _mean_max(_util_delta(pu, au).values())
    return {
        "batch_time_error": batch_time_error(pred, actual),
        "activity_error_mean": act_mean,
        "activity_error_max": act_max,
        "stage_error_mean": stg_mean,
        "stage_error_max": stg_max,
        "duration_error_mean": dur_mean,
        "duration_error_max": dur_max,
        "utilization_delta_max": util_max,
        "bubble_delta": abs(pred.bubble_fraction(pu)
                            - actual.bubble_fraction(au)),
    }


def to_chrome_trace(tl: Timeline, path: str) -> None:
    """Export a timeline as a Chrome trace (chrome://tracing /
    Perfetto). One row per device; compute/comm events color-coded by
    phase."""
    import json
    events = []
    for a in tl.activities:
        events.append({
            "name": a.name, "ph": "X",
            "ts": a.start * 1e6, "dur": max(a.dur * 1e6, 0.01),
            "pid": 0, "tid": a.device,
            "cat": a.kind,
            "args": {"stage": a.stage, "micro": a.micro},
        })
    meta = [{"name": "thread_name", "ph": "M", "pid": 0, "tid": d,
             "args": {"name": f"device {d}"}}
            for d in range(tl.n_devices)]
    with open(path, "w") as f:
        json.dump({"traceEvents": meta + events}, f)
