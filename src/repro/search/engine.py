"""Cached, pruned, multi-cluster strategy search engine (paper §6).

The naive workflow (seed ``grid_search``) rebuilt and re-profiled the
full event timeline per candidate. This engine applies the paper's
unique-event observation to the *search loop*:

* every candidate on a cluster shares one :class:`ProfileCache`
  provider, so an event appearing in many candidates is cost-evaluated
  once per search (``share_cache=False`` restores the naive
  per-candidate profiling for cross-checks and accounting);
* memory-infeasible candidates are skipped before any simulation, and
  candidates whose work lower bound already exceeds the best known
  batch time are pruned before full timeline construction;
* a list of ``ClusterSpec`` targets yields per-cluster rankings plus a
  cross-cluster Pareto frontier over (batch_time, HBM headroom,
  profiling cost);
* with ``megabatch=True`` (the default when the cache is shared) the
  grid's surviving candidates are scored by ONE
  :class:`repro.core.megabatch.MegaBatch` array call per cluster
  instead of a per-cell Python predict: engines come from the
  cluster's :class:`~repro.validate.build_cache.BuildCache` (shared
  positions/builds across schedule variants), the memory mask is an
  array op, and bound-pruning decisions are replayed in grid order
  over the vectorized batch times — entries, rankings and batch times
  are bit-identical to the per-cell path (differential oracle in
  ``tests/test_search_engine.py``).
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Union

from repro.configs.base import ArchConfig
from repro.core.costmodel import ClusterSpec, V5E_POD
from repro.core.events import Strategy, stage_event_set
from repro.core.profiler import AnalyticalProvider, Provider
from repro.core.simulator import DistSim
from repro.search.cache import ProfileCache
from repro.search.prune import (HBM_BUDGET, estimate_memory,
                                work_lower_bound)
from repro.search.space import Candidate, enumerate_candidates


@dataclasses.dataclass
class SearchEntry:
    """One scored candidate. Field order up to ``reason`` is the seed
    ``repro.core.search.SearchEntry`` layout (positional compat)."""
    strategy: Strategy
    batch_time: float               # predicted, or lower bound if pruned
    iters_per_s: float
    bubble_fraction: float
    feasible: bool
    reason: str = ""
    cluster: str = ""
    mem_bytes: float = 0.0
    hbm_headroom: float = 0.0
    profile_time_s: float = 0.0     # unique-event profiling cost
    pruned: bool = False


@dataclasses.dataclass
class SearchStats:
    candidates: int = 0             # grid points x clusters
    evaluated: int = 0              # full timeline constructions
    pruned_memory: int = 0
    pruned_bound: int = 0
    provider_evaluations: int = 0   # real cost-model evaluations
    cache_hits: int = 0
    wall_time_s: float = 0.0
    megabatch_lanes: int = 0        # candidates scored via array calls

    @property
    def candidates_per_s(self) -> float:
        return self.candidates / self.wall_time_s if self.wall_time_s \
            else 0.0


@dataclasses.dataclass
class SearchResult:
    entries: List[SearchEntry]              # all clusters, by batch_time
    by_cluster: Dict[str, List[SearchEntry]]
    pareto: List[SearchEntry]
    stats: SearchStats
    #: full specs of the searched clusters (serialized uniformly in
    #: search_report via ClusterSpec.to_dict, not by registry name)
    cluster_specs: Dict[str, ClusterSpec] = \
        dataclasses.field(default_factory=dict)

    def ranking(self, cluster: Optional[str] = None) -> List[SearchEntry]:
        """Fully-simulated feasible entries, fastest first (Table 2)."""
        pool = self.by_cluster.get(cluster, []) if cluster else self.entries
        return [e for e in pool if e.feasible and not e.pruned]

    def best(self, cluster: Optional[str] = None) -> Optional[SearchEntry]:
        rank = self.ranking(cluster)
        return rank[0] if rank else None


def pareto_frontier(entries: Sequence[SearchEntry]) -> List[SearchEntry]:
    """Non-dominated set: minimize batch_time and profile_time_s,
    maximize hbm_headroom."""

    def dominates(a: SearchEntry, b: SearchEntry) -> bool:
        no_worse = (a.batch_time <= b.batch_time
                    and a.profile_time_s <= b.profile_time_s
                    and a.hbm_headroom >= b.hbm_headroom)
        better = (a.batch_time < b.batch_time
                  or a.profile_time_s < b.profile_time_s
                  or a.hbm_headroom > b.hbm_headroom)
        return no_worse and better

    return [e for e in entries
            if not any(dominates(o, e) for o in entries if o is not e)]


class SearchEngine:
    def __init__(self, cfg: ArchConfig,
                 clusters: Union[ClusterSpec, Sequence[ClusterSpec],
                                 None] = None,
                 provider_factory=AnalyticalProvider,
                 cache: Optional[ProfileCache] = None,
                 share_cache: bool = True,
                 prune: bool = True,
                 check_memory: bool = True,
                 megabatch: bool = True,
                 megabatch_backend: str = "auto"):
        self.cfg = cfg
        if cache is not None:
            self.clusters = cache.clusters
        else:
            if clusters is None:
                clusters = (V5E_POD,)
            elif isinstance(clusters, ClusterSpec):
                clusters = (clusters,)
            self.clusters = list(clusters)
        self.provider_factory = provider_factory
        self.share_cache = share_cache
        self.prune = prune
        self.check_memory = check_memory
        self.cache = cache if cache is not None else (
            ProfileCache.for_clusters(self.clusters, provider_factory)
            if share_cache else None)
        # the mega-batch path compiles engines out of the shared
        # BuildCache; without a shared cache it degrades to the naive
        # per-candidate loop (which is exactly what share_cache=False
        # exists to benchmark)
        self.megabatch = bool(megabatch and self.share_cache)
        self.megabatch_backend = megabatch_backend
        # compiled MegaBatch programs, keyed by engine identity — the
        # BuildCache returns the same engine objects on repeat searches,
        # so a warm search skips compilation and goes straight to eval
        self._megabatch_programs: "OrderedDict" = OrderedDict()

    def _provider(self, cluster: ClusterSpec) -> Provider:
        if self.share_cache:
            return self.cache.provider(cluster)
        return self.provider_factory(cluster)   # naive: fresh per candidate

    def search(self, n_devices: int, global_batch: int, seq: int,
               microbatches: Optional[Sequence[int]] = None,
               schedules: Sequence[str] = ("1f1b",),
               zero1_options: Sequence[bool] = (False,)) -> SearchResult:
        t0 = time.perf_counter()
        stats = SearchStats()
        base_evals = self.cache.evaluations if self.share_cache else 0
        base_hits = self.cache.hits if self.share_cache else 0
        grid = enumerate_candidates(n_devices, global_batch, microbatches,
                                    schedules, zero1_options)
        by_cluster: Dict[str, List[SearchEntry]] = {}
        search_cluster = (self._search_cluster_megabatch if self.megabatch
                          else self._search_cluster)
        for cluster in self.clusters:
            by_cluster[cluster.name] = search_cluster(
                cluster, grid, global_batch, seq, stats)

        entries = sorted((e for es in by_cluster.values() for e in es),
                         key=lambda e: e.batch_time)
        for es in by_cluster.values():
            es.sort(key=lambda e: e.batch_time)
        if self.share_cache:
            stats.provider_evaluations = self.cache.evaluations - base_evals
            stats.cache_hits = self.cache.hits - base_hits
        stats.wall_time_s = time.perf_counter() - t0
        pareto = pareto_frontier(
            [e for e in entries if e.feasible and not e.pruned])
        return SearchResult(entries, by_cluster, pareto, stats,
                            cluster_specs={c.name: c
                                           for c in self.clusters})

    def _search_cluster(self, cluster: ClusterSpec, grid: List[Candidate],
                        global_batch: int, seq: int,
                        stats: SearchStats) -> List[SearchEntry]:
        entries: List[SearchEntry] = []
        best_bt: Optional[float] = None
        budget = cluster.chip.hbm_bytes * HBM_BUDGET
        for cand in grid:
            stats.candidates += 1
            strat, micro = cand.strategy, cand.microbatch
            mem = estimate_memory(self.cfg, strat, micro, seq)
            headroom = budget - mem
            if self.check_memory and headroom <= 0:
                stats.pruned_memory += 1
                entries.append(SearchEntry(
                    strat, float("inf"), 0.0, 1.0, False, "OOM",
                    cluster=cluster.name, mem_bytes=mem,
                    hbm_headroom=headroom))
                continue

            provider = self._provider(cluster)
            sim = DistSim(self.cfg, strat, global_batch, seq, provider)
            positions = sim.positions()
            if self.prune and best_bt is not None:
                lb = work_lower_bound(positions, strat, provider)
                if lb >= best_bt:
                    # batch_time holds a LOWER BOUND, not a prediction;
                    # feasible=False keeps bounds out of naive
                    # `[e for e in entries if e.feasible]` rankings
                    stats.pruned_bound += 1
                    entries.append(SearchEntry(
                        strat, lb, 0.0, 0.0, False, "bound", pruned=True,
                        cluster=cluster.name, mem_bytes=mem,
                        hbm_headroom=headroom))
                    if not self.share_cache:
                        stats.provider_evaluations += \
                            provider.stats.evaluations
                        stats.cache_hits += provider.stats.hits
                    continue

            res = sim.simulate(positions=positions)
            stats.evaluated += 1
            bt = res.batch_time
            ptime = sum(provider.cached_time(e)
                        for e in stage_event_set(positions))
            entries.append(SearchEntry(
                strat, bt, 1.0 / bt if bt else 0.0,
                float(res.bubble_fraction()[0]), True,
                cluster=cluster.name, mem_bytes=mem,
                hbm_headroom=headroom, profile_time_s=ptime))
            if best_bt is None or bt < best_bt:
                best_bt = bt
            if not self.share_cache:
                stats.provider_evaluations += provider.stats.evaluations
                stats.cache_hits += provider.stats.hits
        return entries

    def _search_cluster_megabatch(self, cluster: ClusterSpec,
                                  grid: List[Candidate],
                                  global_batch: int, seq: int,
                                  stats: SearchStats) -> List[SearchEntry]:
        """Array-call variant of :meth:`_search_cluster`.

        Phase 1 applies the memory mask and compiles every surviving
        candidate's engine from the cluster's BuildCache; phase 2 is a
        single :class:`~repro.core.megabatch.MegaBatch` evaluation;
        phase 3 replays the bound-pruning decisions in grid order over
        the vectorized batch times. Because the mega-batch times are
        bit-identical to per-engine predicts, the sequential prune
        trajectory (lower bound vs best-so-far) — and hence every
        entry — reproduces the per-cell path exactly.
        """
        from repro.core.megabatch import MegaBatch

        provider = self.cache.provider(cluster)
        bcache = self.cache.build_cache(cluster)
        budget = cluster.chip.hbm_bytes * HBM_BUDGET

        rows = []        # (cand, mem, headroom, lane | None, lb | None)
        engines = []
        for cand in grid:
            stats.candidates += 1
            strat = cand.strategy
            mem = estimate_memory(self.cfg, strat, cand.microbatch, seq)
            headroom = budget - mem
            if self.check_memory and headroom <= 0:
                stats.pruned_memory += 1
                rows.append((cand, mem, headroom, None, None))
                continue
            eng = bcache.engine_for_cfg(self.cfg, strat, global_batch,
                                        seq)
            lb = (work_lower_bound(eng.build.stages, strat, provider)
                  if self.prune else None)
            rows.append((cand, mem, headroom, len(engines), lb))
            engines.append(eng)

        times = None
        bubbles = None
        if engines:
            # engines come from the BuildCache, so the identity tuple is
            # stable across repeat searches — a warm search reuses the
            # compiled array program and pays only the eval
            key = (cluster.name, tuple(id(e) for e in engines))
            mb = self._megabatch_programs.get(key)
            if mb is None:
                mb = MegaBatch(engines)
                self._megabatch_programs[key] = mb
                while len(self._megabatch_programs) > 8:
                    self._megabatch_programs.popitem(last=False)
            pred = mb.predict(self.megabatch_backend)
            times, bubbles = pred.batch_times, pred.bubble_fractions
            stats.megabatch_lanes += len(engines)

        entries: List[SearchEntry] = []
        best_bt: Optional[float] = None
        for cand, mem, headroom, lane, lb in rows:
            strat = cand.strategy
            if lane is None:
                entries.append(SearchEntry(
                    strat, float("inf"), 0.0, 1.0, False, "OOM",
                    cluster=cluster.name, mem_bytes=mem,
                    hbm_headroom=headroom))
                continue
            if self.prune and best_bt is not None and lb >= best_bt:
                stats.pruned_bound += 1
                entries.append(SearchEntry(
                    strat, lb, 0.0, 0.0, False, "bound", pruned=True,
                    cluster=cluster.name, mem_bytes=mem,
                    hbm_headroom=headroom))
                continue
            bt = float(times[lane])
            stats.evaluated += 1
            ptime = sum(provider.cached_time(e)
                        for e in stage_event_set(
                            engines[lane].build.stages))
            entries.append(SearchEntry(
                strat, bt, 1.0 / bt if bt else 0.0,
                float(bubbles[lane]), True,
                cluster=cluster.name, mem_bytes=mem,
                hbm_headroom=headroom, profile_time_s=ptime))
            if best_bt is None or bt < best_bt:
                best_bt = bt
        return entries
