"""Search reporting: Table 2-style ranking + Pareto + cost accounting.

``search_report`` turns a :class:`SearchResult` into a plain dict
(JSON-serializable) consumed by ``examples/strategy_search.py`` and
``benchmarks/bench_search.py``; ``format_report`` renders it for a
terminal. ``format_table`` is the shared column renderer, also used by
``repro.validate.report``.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

from repro.search.engine import SearchEntry, SearchResult


def format_table(headers: Sequence[str], rows: Sequence[Sequence],
                 aligns: Optional[Sequence[str]] = None) -> List[str]:
    """Render a padded text table: header line + one line per row.
    ``aligns`` is per-column ``"<"``/``">"`` (default: right)."""
    cells = [[str(c) for c in row] for row in rows]
    aligns = list(aligns) if aligns else [">"] * len(headers)
    widths = [max(len(h), *(len(r[i]) for r in cells)) if cells else len(h)
              for i, h in enumerate(headers)]
    def line(row):
        return " ".join(f"{c:{a}{w}s}"
                        for c, a, w in zip(row, aligns, widths)).rstrip()
    return [line(list(headers))] + [line(r) for r in cells]


def _row(rank: int, e: SearchEntry) -> Dict:
    return {
        "rank": rank,
        "strategy": e.strategy.label(),
        "schedule": e.strategy.schedule,
        "microbatches": e.strategy.microbatches,
        "cluster": e.cluster,
        "batch_time_s": e.batch_time,
        "iters_per_s": e.iters_per_s,
        "bubble_pct": 100.0 * e.bubble_fraction,
        "hbm_headroom_gb": e.hbm_headroom / 1e9,
        "profile_time_s": e.profile_time_s,
    }


def search_report(result: SearchResult, top: int = 10,
                  cluster: Optional[str] = None) -> Dict:
    """Structured summary: best strategy, Table 2 ranking, Pareto
    frontier, and the cache/pruning accounting that makes the cached
    engine ≥5x cheaper than naive per-candidate profiling."""
    ranking = result.ranking(cluster)
    st = result.stats
    # best/worst spread is a STRATEGY comparison (Table 2), so both
    # ends must come from the same cluster — on multi-cluster searches
    # the global ranking mixes hardware speeds.
    home = result.ranking(cluster or (ranking[0].cluster if ranking
                                      else None))
    report = {
        "best": _row(1, ranking[0]) if ranking else None,
        "ranking": [_row(i + 1, e) for i, e in enumerate(ranking[:top])],
        "worst": _row(len(home), home[-1]) if home else None,
        "speedup_best_vs_worst": (
            home[-1].batch_time / home[0].batch_time
            if len(home) > 1 else 1.0),
        "pareto": [_row(i + 1, e)
                   for i, e in enumerate(result.pareto)],
        "clusters": sorted(result.by_cluster),
        # full specs (ClusterSpec.to_dict round-trip), not names only —
        # a report over a custom cluster stays self-describing
        "cluster_specs": {name: spec.to_dict()
                          for name, spec in
                          sorted(result.cluster_specs.items())},
        "search": {
            "candidates": st.candidates,
            "evaluated": st.evaluated,
            "pruned_memory": st.pruned_memory,
            "pruned_bound": st.pruned_bound,
            "provider_evaluations": st.provider_evaluations,
            "cache_hits": st.cache_hits,
            "megabatch_lanes": st.megabatch_lanes,
            "wall_time_s": st.wall_time_s,
            "candidates_per_s": st.candidates_per_s,
        },
    }
    return report


def format_report(report: Dict) -> str:
    lines: List[str] = []
    s = report["search"]
    lines.append(
        f"searched {s['candidates']} candidates on "
        f"{len(report['clusters'])} cluster(s) in {s['wall_time_s']:.2f}s "
        f"({s['candidates_per_s']:.1f} cand/s): "
        f"{s['evaluated']} simulated, {s['pruned_memory']} OOM, "
        f"{s['pruned_bound']} bound-pruned")
    lines.append(
        f"profiling: {s['provider_evaluations']} cost evaluations, "
        f"{s['cache_hits']} cache hits")
    lines.append("")
    lines.extend(format_table(
        ["rank", "strategy", "sched", "micro", "cluster", "it/s",
         "bubble%", "hbm GB"],
        [[r["rank"], r["strategy"], r["schedule"], r["microbatches"],
          r["cluster"], f"{r['iters_per_s']:.2f}",
          f"{r['bubble_pct']:.1f}", f"{r['hbm_headroom_gb']:.1f}"]
         for r in report["ranking"]],
        aligns=(">", "<", "<", ">", "<", ">", ">", ">")))
    if report["worst"]:
        w = report["worst"]
        lines.append(
            f"WORST {w['strategy']} {w['schedule']} m={w['microbatches']} "
            f"{w['iters_per_s']:.3f} it/s — best/worst speedup "
            f"{report['speedup_best_vs_worst']:.2f}x (paper: 7.379x)")
    if report["pareto"]:
        lines.append("")
        lines.append("Pareto frontier (batch_time ↓, profiling cost ↓, "
                     "HBM headroom ↑):")
        for r in report["pareto"]:
            lines.append(
                f"  {r['strategy']:12s} {r['schedule']:10s} "
                f"m={r['microbatches']:<4d} {r['cluster']:12s} "
                f"{r['iters_per_s']:.2f} it/s  "
                f"headroom {r['hbm_headroom_gb']:.1f} GB  "
                f"profile {r['profile_time_s']*1e3:.1f} ms")
    return "\n".join(lines)
