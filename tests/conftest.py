import os

# smoke tests and benches must see the single real CPU device — the
# 512-device XLA flag belongs ONLY to repro.launch.dryrun (see spec).
assert "xla_force_host_platform_device_count" not in \
    os.environ.get("XLA_FLAGS", "")

import jax

jax.config.update("jax_platform_name", "cpu")
