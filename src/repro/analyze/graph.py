"""Static event-graph verifier (pure numpy — no jax).

The engine's task graph is an IR (DistIR's observation: a distributed
program you can analyze before you simulate it). This pass re-derives
the dependency structure of an :class:`~repro.core.engine
.EventFlowEngine` / :class:`~repro.core.megabatch.MegaBatch` from
first principles — independently of the schedulers that will consume
it — and checks the invariants everything downstream silently assumes:

======  ===========================================================
rule    invariant
======  ===========================================================
G001    dependency graph is acyclic (an independent Kahn pass drains)
G002    every dependency names a task that exists (no dangling refs)
G003    task coverage: each (phase, position, microbatch) appears
        exactly once, on the device its position maps to
G004    ``topo_order()`` is a valid linearization of the true edges —
        the MegaBatch compile contract
G005    MegaBatch array program validity: out-slots are a permutation,
        padding writes the trash slot, every dependency (≤3 planes per
        task, by construction) reads a slot already written at an
        earlier step of the same candidate or the dummy slot
        (write-before-read), delays/durations finite and non-negative
G006    device-serialization chains: per-device task metadata aligned;
        in the compiled program, dep0 follows the slot-predecessor
        chain with exactly one chain head per non-empty device
G007    scenario consistency: decode graphs carry per-step KV ``hbm``
        reads and monotone non-negative arrival floors; serving
        engines are forward-only (no B tasks, no sync/optimizer)
G008    perturbation well-formedness: straggler/fault ranks inside the
        (dp, pp, mp) mesh, fault steps inside the run, and every fault
        prefix survivable by ``replan_mesh`` (model group intact)
G009    event-mean sanity: profiled means finite and non-negative
G010    static HBM over-capacity: ``estimate_memory`` exceeds the
        ``HBM_BUDGET`` share of the chip's HBM (cell-level check)
======  ===========================================================

Everything is duck-typed over the engine/build attributes so this
module imports nothing from :mod:`repro.core` at module scope — the
constructors can call into it lazily with no import cycle.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.analyze.findings import Finding

_MAX_PER_RULE = 8      # cap repeated findings of one rule per subject


class _Reporter:
    """Collects findings, capping repeats of one rule so a systematic
    breakage (every microbatch dangling) stays readable."""

    def __init__(self, where: str):
        self.where = where
        self.findings: List[Finding] = []
        self._counts: Dict[str, int] = {}

    def add(self, rule: str, message: str) -> None:
        n = self._counts.get(rule, 0)
        self._counts[rule] = n + 1
        if n < _MAX_PER_RULE:
            self.findings.append(
                Finding(rule=rule, message=message, where=self.where))
        elif n == _MAX_PER_RULE:
            self.findings.append(Finding(
                rule=rule, where=self.where,
                message="further findings of this rule suppressed"))


def _label(engine) -> str:
    strat = getattr(engine, "strat", None)
    scen = getattr(engine, "scenario", None)
    parts = []
    if strat is not None:
        parts.append(strat.label())
        parts.append(strat.schedule)
    if scen is not None and not scen.is_train:
        parts.append(scen.label())
    return "/".join(parts) or engine.__class__.__name__


# --------------------------------------------------------------------------
# engine-level graph checks
# --------------------------------------------------------------------------

def _check_metadata(engine, rep: _Reporter) -> bool:
    """G006: the five per-device task metadata lists stay aligned."""
    ok = True
    pp = engine.strat.pp
    lists = (engine.task_isf, engine.task_pos, engine.task_micro,
             engine.task_name, engine.task_p2p_name)
    if any(len(lst) != pp for lst in lists):
        rep.add("G006", f"task metadata covers "
                        f"{sorted({len(lst) for lst in lists})} devices, "
                        f"strategy has pp={pp}")
        return False
    for d in range(pp):
        lens = {len(lst[d]) for lst in lists}
        if len(lens) != 1:
            rep.add("G006", f"device {d}: task metadata lists disagree "
                            f"on length ({sorted(lens)})")
            ok = False
    return ok

def _task_edges(engine, rep: _Reporter
                ) -> Tuple[List[Tuple[int, int]], List[List[int]]]:
    """Re-derive the task nodes and dependency edges from metadata.

    Returns ``(nodes, preds)`` where ``nodes[t] = (device, index)`` and
    ``preds[t]`` lists the task ids that must complete before ``t``.
    Emits G002 (dangling producer) and G003 (coverage/placement) along
    the way. The edge rules intentionally restate — rather than call —
    the engine's ready conditions, so a bug in the scheduler and a bug
    in the checker cannot cancel out.
    """
    pp, n_pos, m = engine.strat.pp, engine.n_pos, engine.m
    decode = engine.scenario.kind == "decode"
    train = engine.scenario.is_train

    nodes: List[Tuple[int, int]] = []
    meta: List[Tuple[bool, int, int]] = []        # (isf, pos, mic)
    producer: Dict[Tuple[str, int, int], int] = {}
    for d in range(pp):
        for i, (isf, pos, mic) in enumerate(zip(
                engine.task_isf[d], engine.task_pos[d],
                engine.task_micro[d])):
            t = len(nodes)
            nodes.append((d, i))
            meta.append((bool(isf), int(pos), int(mic)))
            key = ("F" if isf else "B", int(pos), int(mic))
            if key in producer:
                rep.add("G003", f"duplicate task {key} on devices "
                                f"{nodes[producer[key]][0]} and {d}")
            else:
                producer[key] = t
            if not (0 <= pos < n_pos):
                rep.add("G003", f"task {key} on device {d}: position "
                                f"{pos} outside [0, {n_pos})")
            elif pos % pp != d:
                rep.add("G003", f"task {key} placed on device {d}, "
                                f"position maps to {pos % pp}")
            if not (0 <= mic < m):
                rep.add("G003", f"task {key} on device {d}: microbatch "
                                f"{mic} outside [0, {m})")

    # coverage: the scenario dictates exactly which tasks must exist
    phases = ("F", "B") if train else ("F",)
    for ph in phases:
        for pos in range(n_pos):
            for mic in range(m):
                if (ph, pos, mic) not in producer:
                    rep.add("G003",
                            f"missing task {(ph, pos, mic)} — "
                            f"unreachable downstream consumers")
    if not train:
        stray = sorted(k for k in producer if k[0] == "B")
        for k in stray[:3]:
            rep.add("G007", f"forward-only scenario has backward "
                            f"task {k}")

    preds: List[List[int]] = [[] for _ in nodes]

    def dep(t: int, key: Tuple[str, int, int]) -> None:
        p = producer.get(key)
        if p is None:
            isf, pos, mic = meta[t]
            rep.add("G002",
                    f"task {('F' if isf else 'B', pos, mic)} depends on "
                    f"missing producer {key} (dangling dependency)")
        else:
            preds[t].append(p)

    prev: List[Optional[int]] = [None] * pp
    for t, ((d, _i), (isf, pos, mic)) in enumerate(zip(nodes, meta)):
        if prev[d] is not None:
            preds[t].append(prev[d])          # device serialization
        prev[d] = t
        if not (0 <= pos < n_pos and 0 <= mic < m):
            continue                          # already reported (G003)
        if isf:
            if pos > 0:
                dep(t, ("F", pos - 1, mic))
            elif decode and mic > 0:
                dep(t, ("F", n_pos - 1, mic - 1))   # token feedback
        else:
            dep(t, ("F", pos, mic))
            if pos < n_pos - 1:
                dep(t, ("B", pos + 1, mic))
    return nodes, preds


def _kahn(nodes, preds, rep: _Reporter) -> bool:
    """G001: independent acyclicity check over the re-derived edges."""
    n = len(nodes)
    succ: List[List[int]] = [[] for _ in range(n)]
    indeg = [0] * n
    for t, ps in enumerate(preds):
        indeg[t] = len(ps)
        for p in ps:
            succ[p].append(t)
    queue = [t for t in range(n) if indeg[t] == 0]
    drained = 0
    while queue:
        t = queue.pop()
        drained += 1
        for s in succ[t]:
            indeg[s] -= 1
            if indeg[s] == 0:
                queue.append(s)
    if drained != n:
        stuck = [nodes[t] for t in range(n) if indeg[t] > 0]
        rep.add("G001", f"dependency cycle: {n - drained} task(s) never "
                        f"become ready, e.g. (device, index) "
                        f"{stuck[:4]}")
        return False
    return True


def _check_topo(engine, nodes, preds, rep: _Reporter) -> None:
    """G004: ``topo_order()`` linearizes the true edges — the contract
    MegaBatch compiles against.

    Side-effect-free: ``topo_order()`` memoizes into ``engine._topo``,
    and a verification pass must not leave that cache behind — tests
    mutate task lists after construction and expect the stale order to
    be recomputed, not served from the verifier's snapshot.
    """
    prior = getattr(engine, "_topo", None)
    try:
        order = engine.topo_order()
    except Exception as exc:     # malformed metadata can crash it with
        rep.add("G004",          # anything — report, never propagate
                f"topo_order() failed on an acyclic graph: "
                f"{exc.__class__.__name__}: {exc}")
        return
    finally:
        engine._topo = prior
    index = {node: t for t, node in enumerate(nodes)}
    seen: Dict[Tuple[int, int], int] = {}
    for step, di in enumerate(order):
        di = (int(di[0]), int(di[1]))
        if di not in index:
            rep.add("G004", f"topo_order() yields unknown task {di}")
            return
        if di in seen:
            rep.add("G004", f"topo_order() repeats task {di}")
            return
        seen[di] = step
    if len(order) != len(nodes):
        rep.add("G004", f"topo_order() covers {len(order)}/{len(nodes)} "
                        f"tasks")
        return
    for t, ps in enumerate(preds):
        for p in ps:
            if seen[nodes[p]] >= seen[nodes[t]]:
                rep.add("G004",
                        f"topo_order() places dependency {nodes[p]} at "
                        f"step {seen[nodes[p]]}, after its consumer "
                        f"{nodes[t]} at step {seen[nodes[t]]}")
                return


def _check_scenario(engine, rep: _Reporter) -> None:
    """G007: scenario-specific graph shape."""
    scen = engine.scenario
    if scen.is_train:
        if any(a != 0.0 for a in getattr(engine, "arrival", ())):
            rep.add("G007", "train engine carries arrival floors")
        return
    # serving: forward-only epilogue
    if getattr(engine, "sync", False):
        rep.add("G007", "serving engine has a gradient sync")
    if getattr(engine, "has_opt", False):
        rep.add("G007", "serving engine has an optimizer step")
    if scen.kind != "decode":
        return
    arrivals = tuple(getattr(scen, "arrivals", ()))
    if any(a < 0 for a in arrivals):
        rep.add("G007", f"negative decode arrival floor in {arrivals}")
    if list(arrivals) != sorted(arrivals):
        rep.add("G007", f"decode arrival floors not monotone "
                        f"non-decreasing: {arrivals}")
    if len(arrivals) > scen.steps:
        rep.add("G007", f"{len(arrivals)} arrival floors for "
                        f"{scen.steps} decode steps")
    if getattr(engine, "fb_base", 0.0) < 0:
        rep.add("G007", "negative token-feedback p2p mean")
    # per-step KV reads: every stage whose layers own KV/SSM state must
    # read it from HBM each step; at least one stage must
    stages = getattr(engine, "stages", [])
    any_hbm = False
    for st in stages:
        kinds = [e.kind for e in st.fwd.events] if st.fwd else []
        has_hbm = "hbm" in kinds
        any_hbm = any_hbm or has_hbm
        layers = getattr(st, "layers", None) or []
        if any(getattr(l, "kv_read_bytes", 0.0) for l in layers) \
                and not has_hbm:
            rep.add("G007", f"decode stage {st.index} owns KV state but "
                            f"its forward bundle has no hbm read event")
    if stages and not any_hbm:
        rep.add("G007", "decode graph has no per-step KV hbm read "
                        "events in any stage")


def _check_means(build, rep: _Reporter) -> None:
    """G009: profiled means are finite and non-negative."""

    def arr(name, a):
        a = np.asarray(a, dtype=float)
        if a.size and (not np.all(np.isfinite(a)) or np.any(a < 0)):
            rep.add("G009", f"{name} contains negative or non-finite "
                            f"event means")

    for p, (fm, bm) in enumerate(zip(build.fwd_event_means,
                                     build.bwd_event_means)):
        arr(f"fwd_event_means[{p}]", fm)
        arr(f"bwd_event_means[{p}]", bm)
    arr("fwd_base", build.fwd_base)
    arr("bwd_base", build.bwd_base)
    arr("p2p_base", build.p2p_base)
    arr("ar_base", build.ar_base)
    arr("opt_base", build.opt_base)
    fb = getattr(build, "fb_base", 0.0)
    if not (math.isfinite(fb) and fb >= 0):
        rep.add("G009", f"fb_base = {fb!r}")


# --------------------------------------------------------------------------
# public entry points
# --------------------------------------------------------------------------

def verify_engine(engine) -> List[Finding]:
    """All graph checks for one :class:`EventFlowEngine`."""
    rep = _Reporter(_label(engine))
    _check_means(engine.build, rep)
    _check_scenario(engine, rep)
    if not _check_metadata(engine, rep):
        return rep.findings           # unaligned lists: nothing below holds
    nodes, preds = _task_edges(engine, rep)
    if _kahn(nodes, preds, rep):
        # only consult topo_order() on an acyclic graph — on a cyclic
        # one it deadlocks by design and G001 already says why
        _check_topo(engine, nodes, preds, rep)
    return rep.findings


def verify_build(build) -> List[Finding]:
    """Verify an :class:`EngineBuild` or a full engine.

    A bare build has no schedule yet, so only the schedule-independent
    checks (G009 means, scenario shape of the stages) apply; passing an
    engine (anything with task metadata) runs the full graph pass.
    """
    if hasattr(build, "task_isf"):
        return verify_engine(build)
    rep = _Reporter(f"build/{_label(build)}")
    _check_means(build, rep)
    return rep.findings


def verify_megabatch(mb) -> List[Finding]:
    """G005/G006 over a compiled :class:`MegaBatch` array program."""
    rep = _Reporter(f"megabatch[K={mb.K}]")
    trash = mb.total + 1
    dummy = 0
    if mb.K == 0:
        return rep.findings
    base = 1
    for k, eng in enumerate(mb.engines):
        n = eng.total_tasks
        col_where = f"candidate {k} ({_label(eng)})"
        out = mb._out[:, k]
        # out-slots: a permutation of this candidate's slot range,
        # padding steps parked on the trash slot
        want = np.arange(base, base + n)
        if not np.array_equal(np.sort(out[:n]), want):
            rep.add("G005", f"{col_where}: out-slots are not a "
                            f"permutation of [{base}, {base + n})")
            base += n
            continue
        if not np.all(out[n:] == trash):
            rep.add("G005", f"{col_where}: padding steps write real "
                            f"slots instead of the trash slot")
        # write-before-read: every dep plane reads the dummy slot or a
        # slot this candidate wrote at an EARLIER step. A dependency on
        # a later step is exactly what an unhonorable extra dependency
        # (the >3-deps defect class) compiles into.
        step_of = np.full(mb.n_slots, mb.T, dtype=np.int64)
        step_of[out[:n]] = np.arange(n)
        steps = np.arange(mb.T)
        n_heads = 0
        for plane, name in ((mb._dep0, "dep0"), (mb._dep1, "dep1"),
                            (mb._dep2, "dep2")):
            d = plane[:n, k]
            if np.any((d < 0) | (d >= mb.n_slots)) or np.any(d == trash):
                rep.add("G005", f"{col_where}: {name} reads a slot "
                                f"outside the program")
                continue
            foreign = (d != dummy) & ((d < base) | (d >= base + n))
            if np.any(foreign):
                rep.add("G005", f"{col_where}: {name} reads another "
                                f"candidate's slots at steps "
                                f"{np.nonzero(foreign)[0][:4].tolist()}")
            late = (d != dummy) & (step_of[d] >= steps[:n])
            if np.any(late):
                js = np.nonzero(late)[0][:4].tolist()
                rep.add("G005", f"{col_where}: {name} reads slots not "
                                f"yet written at steps {js} "
                                f"(write-before-read violated)")
            if name == "dep0":
                # G006: device serialization — dep0 is the previous
                # slot on the same device (slots are assigned in
                # device-major schedule order) or a chain head
                n_heads = int(np.sum(d == dummy))
                bad = (d != dummy) & (d != out[:n] - 1)
                if np.any(bad):
                    rep.add("G006", f"{col_where}: dep0 breaks the "
                                    f"device-serialization chain at "
                                    f"steps "
                                    f"{np.nonzero(bad)[0][:4].tolist()}")
        n_dev = sum(1 for lst in eng.task_isf if lst)
        if n_heads != n_dev:
            rep.add("G006", f"{col_where}: {n_heads} serialization "
                            f"chain heads for {n_dev} non-empty "
                            f"devices")
        for name, a in (("del1", mb._del1[:n, k]),
                        ("del2", mb._del2[:n, k]),
                        ("dur", mb._dur[:n, k])):
            if not np.all(np.isfinite(a)) or np.any(a < 0):
                rep.add("G005", f"{col_where}: {name} has negative or "
                                f"non-finite entries")
        base += n
    # epilogue arrays
    if np.any((mb._seg < 0) | (mb._seg >= max(1, mb.K * mb.ppmax))):
        rep.add("G005", "segment ids outside the (K, ppmax) grid")
    if np.any((mb._free_slot < 0) | (mb._free_slot > mb.total)):
        rep.add("G005", "free-slot ids outside the task slot range")
    return rep.findings


def verify_perturbation(perturb, strat) -> List[Finding]:
    """G008 over a :class:`Perturbation` against one strategy mesh."""
    rep = _Reporter(f"{perturb.label()} on {strat.label()}")
    world = strat.dp * strat.pp * strat.mp
    for s in perturb.stragglers:
        if s.rank >= world:
            rep.add("G008", f"straggler rank {s.rank} outside the "
                            f"{world}-device mesh")
    for f in perturb.faults:
        if f.rank >= world:
            rep.add("G008", f"fault rank {f.rank} outside the "
                            f"{world}-device mesh")
        if f.at_step >= perturb.steps:
            rep.add("G008", f"fault at step {f.at_step} outside the "
                            f"{perturb.steps}-step run")
    # survivability: precompute what simulate_degraded would replan
    from repro.train.fault_tolerance import replan_mesh
    mp_model = strat.mp * strat.pp
    for dead in range(1, len(perturb.faults) + 1):
        survivors = world - dead
        f = perturb.faults[dead - 1]
        try:
            plan = replan_mesh(survivors, mp_model)
        except ValueError as exc:
            rep.add("G008", f"fault at step {f.at_step}: replan "
                            f"impossible ({exc})")
            continue
        if plan.model != mp_model:
            rep.add("G008",
                    f"unrecoverable fault at step {f.at_step}: "
                    f"{survivors} survivors cannot hold the "
                    f"mp*pp={mp_model} model group")
    return rep.findings


def verify_cell_memory(cfg, strat, microbatch: int, seq: int,
                       hbm_bytes: float, scenario=None) -> List[Finding]:
    """G010: static HBM over-capacity for one (model, strategy) cell."""
    from repro.core.scenario import TRAIN
    from repro.search.prune import HBM_BUDGET, hbm_headroom
    scenario = TRAIN if scenario is None else scenario
    rep = _Reporter(f"{cfg.name}/{strat.label()}/{scenario.label()}")
    head = hbm_headroom(cfg, strat, microbatch, seq, hbm_bytes,
                        scenario=scenario)
    if head < 0:
        rep.add("G010",
                f"estimated memory exceeds the {HBM_BUDGET:.0%} HBM "
                f"budget by {-head / 1e9:.2f} GB "
                f"(hbm={hbm_bytes / 1e9:.0f} GB)")
    return rep.findings
