"""Benchmark harness — one function per paper table/figure plus the
roofline summary. Prints ``name,us_per_call,derived`` CSV.

    PYTHONPATH=src python -m benchmarks.run [--only fig8]
"""
from __future__ import annotations

import argparse
import os
import sys
import time


def roofline_summary():
    """Summarize the dry-run roofline CSVs (if the sweep has been run)."""
    rows = []
    for tag, path in (("optimized", "results/dryrun_optimized.csv"),
                      ("baseline", "results/dryrun_baseline.csv")):
        if not os.path.exists(path):
            rows.append((f"roofline/{tag}", 0.0, "missing (run dryrun)"))
            continue
        with open(path) as f:
            lines = f.read().strip().splitlines()[1:]
        fracs, dominants = [], {}
        for line in lines:
            parts = line.split(",")
            dominants[parts[10]] = dominants.get(parts[10], 0) + 1
            fracs.append(float(parts[12]))
        import numpy as np
        rows.append((f"roofline/{tag}", 0.0,
                     f"cells={len(lines)} mean_frac={np.mean(fracs):.3f} "
                     f"dominant={dominants}"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter on benchmark name")
    args = ap.parse_args()

    from benchmarks import paper_figs

    benches = list(paper_figs.ALL) + [roofline_summary]
    print("name,us_per_call,derived")
    failures = 0
    for bench in benches:
        if args.only and args.only not in bench.__name__:
            continue
        t0 = time.perf_counter()
        try:
            rows = bench()
        except Exception as e:      # pragma: no cover
            print(f"{bench.__name__},0,ERROR:{e!r}")
            failures += 1
            continue
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived}")
        sys.stderr.write(f"[{bench.__name__}: "
                         f"{time.perf_counter()-t0:.1f}s]\n")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
