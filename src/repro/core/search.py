"""Use-case: automatic hybrid-parallel strategy search (paper §6).

Grid-search over (MP, PP, DP, microbatches, schedule) for a fixed device
count, scoring each strategy with DistSim — no cluster required. Also
supports a memory-feasibility filter (HBM capacity) and returns the full
ranking, matching the paper's Fig. 12 / Table 2 workflow.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

from repro.configs.base import ArchConfig
from repro.core.costmodel import V5E_POD
from repro.core.events import Strategy
from repro.core.profiler import AnalyticalProvider, Provider
from repro.core.simulator import DistSim


@dataclasses.dataclass
class SearchEntry:
    strategy: Strategy
    batch_time: float
    iters_per_s: float
    bubble_fraction: float
    feasible: bool
    reason: str = ""


def _powers_of_two(n: int) -> List[int]:
    out, p = [], 1
    while p <= n:
        out.append(p)
        p *= 2
    return out


def memory_feasible(cfg: ArchConfig, strat: Strategy, microbatch: int,
                    seq: int, hbm_bytes: float) -> bool:
    """Rough per-device HBM check: params/mp/pp x (w + grad + 2 adam fp32)
    + activations of one microbatch per live stage."""
    n = cfg.n_params()
    state_bytes = n / (strat.mp * strat.pp) * (2 + 2 + 8 / (
        strat.dp if strat.zero1 else 1))
    act = 2.0 * microbatch * seq * cfg.d_model * 4   # rough live acts
    return state_bytes + act < hbm_bytes * 0.92


def grid_search(cfg: ArchConfig, n_devices: int, global_batch: int,
                seq: int, provider: Optional[Provider] = None,
                microbatches: Optional[Sequence[int]] = None,
                schedules: Sequence[str] = ("1f1b",),
                check_memory: bool = False) -> List[SearchEntry]:
    provider = provider or AnalyticalProvider(V5E_POD)
    entries: List[SearchEntry] = []
    for mp in _powers_of_two(n_devices):
        for pp in _powers_of_two(n_devices // mp):
            dp = n_devices // (mp * pp)
            if mp * pp * dp != n_devices or global_batch % dp:
                continue
            mb_opts = microbatches or sorted({
                m for m in _powers_of_two(global_batch // dp)
                if m >= min(pp, global_batch // dp)})
            for m in mb_opts:
                if (global_batch // dp) % m:
                    continue
                for sch in schedules:
                    strat = Strategy(mp=mp, pp=pp, dp=dp, microbatches=m,
                                     schedule=sch)
                    micro = global_batch // (dp * m)
                    if check_memory and not memory_feasible(
                            cfg, strat, micro, seq,
                            provider.cluster.chip.hbm_bytes):
                        entries.append(SearchEntry(
                            strat, float("inf"), 0.0, 1.0, False, "OOM"))
                        continue
                    res = DistSim(cfg, strat, global_batch, seq,
                                  provider).predict()
                    entries.append(SearchEntry(
                        strat, res.batch_time, res.throughput_iters,
                        res.bubble_fraction, True))
    entries.sort(key=lambda e: e.batch_time)
    return entries
