"""GPT-2-345M — paper evaluation model (Fig. 8/9). [Radford et al. 2019]

24L d_model=1024 16H d_ff=4096 vocab=50257.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="gpt2_345m",
    family="dense",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=50257,
    qkv_bias=True,
    mlp_gelu=True,
    tie_embeddings=True,
    shapes=("train_4k", "prefill_32k", "decode_32k"),
    source="GPT-2 (paper eval model)",
))
