"""Tier-1 paper-fidelity gate (paper §5): every non-xfail cell of the
smoke matrix must show predict-vs-replay batch-time error ≤ 4% and
per-device activity error ≤ 5%; the sweep report JSON round-trips; and
the aggregated metrics match the committed goldens, so any drift in the
event/timeline core fails here before it ships.
"""
import dataclasses
import json
import os

import pytest

from repro.core import A40_CLUSTER, AnalyticalProvider
from repro.validate import (CellMetrics, Thresholds, compare_timelines,
                            run_cell, run_sweep, smoke_matrix)
from repro.validate.report import (dump, dumps, format_validation_report,
                                   load, load_path)

GOLDEN = os.path.join(os.path.dirname(__file__), "goldens",
                      "validation_smoke.json")
MATRIX = smoke_matrix()
SEEDS = (0, 1, 2)
THRESHOLDS = Thresholds()


@pytest.fixture(scope="module")
def sweep():
    return run_sweep(MATRIX, cluster=A40_CLUSTER, seeds=SEEDS,
                     thresholds=THRESHOLDS)


@pytest.fixture(scope="module")
def by_label(sweep):
    return {c.cell.label(): c for c in sweep.cells}


@pytest.mark.parametrize("label", [c.label() for c in MATRIX])
def test_cell_within_paper_targets(by_label, label):
    """§5 acceptance: ≤4% batch-time error, ≤5% activity error."""
    res = by_label[label]
    if res.cell.xfail:
        if res.passed:
            pytest.xfail(f"xfail cell passed (un-mark it): {label}")
        pytest.xfail(res.cell.xfail)
    m = res.metrics
    assert m.batch_time_error <= 0.04, (label, m.batch_time_error)
    assert m.activity_error_max <= 0.05, (label, m.activity_error_max)
    assert res.passed, (label, res.violations)


def test_sweep_gates_as_a_whole(sweep):
    assert sweep.passed, [c.cell.label() for c in sweep.failures]
    assert not sweep.xpasses


def test_report_roundtrip(sweep):
    """Acceptance: validate.report.load(dump(r)) == r, also through an
    actual JSON string (tuples/lists normalized)."""
    assert load(dump(sweep)) == sweep
    assert load(json.loads(dumps(sweep))) == sweep
    assert load(dumps(sweep)) == sweep


def test_report_save_load_path(sweep, tmp_path):
    from repro.validate.report import save
    p = str(tmp_path / "report.json")
    save(sweep, p)
    assert load_path(p) == sweep


def test_goldens_match(sweep):
    """Aggregated metrics are deterministic (fixed seeds, analytical
    provider) — they must match the committed baseline to ~1e-6."""
    golden = load_path(GOLDEN)
    assert golden.passed
    cur = {c.cell.label(): c for c in sweep.cells}
    gold = {c.cell.label(): c for c in golden.cells}
    assert set(cur) == set(gold)
    for label, g in gold.items():
        c = cur[label]
        assert c.cell == g.cell
        for f in dataclasses.fields(CellMetrics):
            a = getattr(c.metrics, f.name)
            b = getattr(g.metrics, f.name)
            assert a == pytest.approx(b, rel=1e-6, abs=1e-9), \
                (label, f.name)


def test_goldens_stable_under_batched_replay(sweep):
    """The batching refactor must need NO golden regeneration: batched
    replay with the golden seeds reproduces the committed per-cell
    batch times EXACTLY (replay is bit-identical to the sequential
    path the goldens were generated with — any drift here is a
    batching bug, not a model change)."""
    golden = load_path(GOLDEN)
    assert golden.seeds == list(SEEDS) == sweep.seeds
    for g, c in zip(golden.cells, sweep.cells):
        assert g.cell == c.cell
        assert c.pred_batch_time == g.pred_batch_time
        assert c.replay_batch_times == g.replay_batch_times


def test_run_cell_batched_matches_sequential():
    """Tier-1 differential: the array-native batched cell evaluation
    must reproduce the legacy sequential path — bit-identical batch
    times, metrics equal to float tolerance (the reduction tree
    differs), identical verdicts."""
    provider = AnalyticalProvider(A40_CLUSTER)
    for cell in MATRIX[:4]:
        a = run_cell(cell, provider, seeds=SEEDS, batched=True)
        b = run_cell(cell, provider, seeds=SEEDS, batched=False)
        assert a.pred_batch_time == b.pred_batch_time
        assert a.replay_batch_times == b.replay_batch_times
        for ma, mb in zip(a.per_seed + [a.metrics],
                          b.per_seed + [b.metrics]):
            for f in dataclasses.fields(CellMetrics):
                assert getattr(ma, f.name) == pytest.approx(
                    getattr(mb, f.name), rel=1e-9, abs=1e-12), \
                    (cell.label(), f.name)
        assert a.violations == b.violations


def test_smoke_sweep_materializes_no_activities():
    """Acceptance: the validate sweep must run with ZERO Activity
    materialization — batch times, utilization and all §5 metrics come
    straight from the engine arrays."""
    from repro.core import LazyTimeline
    before = LazyTimeline.materializations
    res = run_sweep(MATRIX, cluster=A40_CLUSTER, seeds=(0, 1))
    assert res.cells
    assert LazyTimeline.materializations == before


def test_sweep_deterministic():
    """Same cell, fresh providers → bit-identical metrics (no hidden
    cache-order or global-RNG dependence)."""
    cell = MATRIX[0]
    a = run_cell(cell, AnalyticalProvider(A40_CLUSTER), seeds=SEEDS)
    b = run_cell(cell, AnalyticalProvider(A40_CLUSTER), seeds=SEEDS)
    assert a.metrics == b.metrics
    assert a.replay_batch_times == b.replay_batch_times


def test_thresholds_actually_trip():
    """The gate can fail: impossible thresholds flag every cell."""
    strict = Thresholds(batch_time=0.0, activity=0.0, stage=0.0,
                        utilization=0.0)
    res = run_sweep(MATRIX[:2], cluster=A40_CLUSTER, seeds=(0,),
                    thresholds=strict)
    assert not res.passed
    assert all(c.violations for c in res.cells)
    rep = dump(res)
    assert rep["n_failures"] == len(res.cells)
    assert "FAIL" in format_validation_report(rep)


def test_xfail_cells_report_but_do_not_gate():
    bad = dataclasses.replace(MATRIX[0], xfail="synthetic known-bad")
    res = run_sweep([bad], cluster=A40_CLUSTER, seeds=(0,),
                    thresholds=Thresholds(batch_time=0.0, activity=0.0,
                                          stage=0.0, utilization=0.0))
    assert not res.cells[0].passed
    assert res.passed                   # xfail cell doesn't gate
    assert not res.failures
    assert "xfail" in format_validation_report(res)


def test_inf_metrics_stay_strict_json(sweep):
    """A degenerate-replay report (infinite error) must still be
    RFC-8259 JSON — no bare 'Infinity' tokens — and round-trip."""
    bad = load(dump(sweep))
    c = bad.cells[0]
    c.metrics = dataclasses.replace(c.metrics,
                                    batch_time_error=float("inf"))
    c.per_seed = ([dataclasses.replace(c.per_seed[0],
                                       batch_time_error=float("inf"))]
                  + c.per_seed[1:])
    s = dumps(bad)
    assert "Infinity" not in s
    json.loads(s)                       # strict parse succeeds
    assert load(s) == bad
    assert load(s).cells[0].metrics.batch_time_error == float("inf")
    text = format_validation_report(bad)    # must render, not raise
    assert "inf" in text


def test_schema_version_checked(sweep):
    d = dump(sweep)
    d["schema"] = 999
    with pytest.raises(ValueError, match="schema"):
        load(d)
    with pytest.raises(ValueError, match="schema"):
        load({"cells": []})                 # missing version entirely


def test_degenerate_oracle_trips_gate():
    """An empty replay timeline vs a real prediction is infinite error,
    not perfect agreement — the harness must flag it."""
    from repro.core import AnalyticalProvider, DistSim, Timeline
    from repro.core.timeline import batch_time_error
    cell = MATRIX[0]
    sim = DistSim(cell.config(), cell.strategy, cell.global_batch,
                  cell.seq, AnalyticalProvider(A40_CLUSTER))
    pred = sim.simulate().timeline()
    empty = Timeline([], n_devices=pred.n_devices)
    assert batch_time_error(pred, empty) == float("inf")
    m = compare_timelines(pred, empty)
    assert THRESHOLDS.violations(m)


def test_worst_seed_threshold_gates():
    """A single bad replay seed trips the gate even when the seed-mean
    is within budget."""
    thr = Thresholds(batch_time=1.0, batch_time_worst=0.0, activity=1.0,
                     stage=1.0, utilization=1.0)
    res = run_sweep(MATRIX[:1], cluster=A40_CLUSTER, seeds=SEEDS,
                    thresholds=thr)
    assert res.cells[0].violations == ["batch_time_worst"]
    assert not res.passed


def test_worst_seed_tracked(sweep):
    for c in sweep.cells:
        assert c.metrics.worst_batch_time_error == pytest.approx(
            max(m.batch_time_error for m in c.per_seed))
        assert c.metrics.worst_batch_time_error \
            >= c.metrics.batch_time_error - 1e-12


def test_metrics_zero_for_identical_timelines():
    from repro.core import DistSim
    cell = MATRIX[0]
    sim = DistSim(cell.config(), cell.strategy, cell.global_batch,
                  cell.seq, AnalyticalProvider(A40_CLUSTER))
    tl = sim.simulate().timeline()
    m = compare_timelines(tl, tl)
    assert m == CellMetrics()


def test_format_report_lists_every_cell(sweep):
    text = format_validation_report(sweep)
    for c in sweep.cells:
        assert c.cell.label() in text
    assert "PASSED" in text
