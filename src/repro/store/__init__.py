"""repro.store — disk-backed persistent profile store + query service.

The paper's unique-event dedup pushed to fleet scale: one
content-addressed store of profiled event times and engine builds,
shared across processes (nightly reruns, search invocations, sweep
executor workers), with a thin simulator-as-a-service front-end on top:

    from repro.store import ProfileStore, ServeQuery
    from repro.core.simulator import DistSim

    run_sweep(cells, store="profile_store/")       # warms the store
    server = DistSim.serve("profile_store/")       # zero re-profiling
    answers = server.answer_batch([ServeQuery(...), ...])

Store-served sweeps, searches and queries are bit-identical to cold
in-process runs (differential tests in ``tests/test_store.py``).
"""
from repro.store.persistent import PersistentBuildCache
from repro.store.profile_store import (FORMAT_VERSION, ProfileStore,
                                       StoreStats, build_key_json,
                                       event_from_dict, event_key,
                                       event_to_dict, open_store,
                                       provider_namespace)
from repro.store.serve import ServeAnswer, ServeQuery, StrategyServer

__all__ = [
    "FORMAT_VERSION", "ProfileStore", "StoreStats", "build_key_json",
    "event_from_dict", "event_key", "event_to_dict", "open_store",
    "provider_namespace", "PersistentBuildCache", "ServeAnswer",
    "ServeQuery", "StrategyServer",
]
