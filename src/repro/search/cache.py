"""Shared profile/event cache for strategy search.

The paper's core trick (Observation 1): events are identical across
devices, microbatches — and, crucially for search, across *candidate
strategies*. Two strategies with the same MP degree share every layer
compute event; collectives recur across grid points. A ``ProfileCache``
holds one profiling provider per target cluster and is shared by every
candidate the engine scores, so each unique event is cost-evaluated
once per search instead of once per candidate.

Event identity is structural (``Event`` is a frozen dataclass keyed on
kind/op/sharded shapes/participants/scope), so the provider's dict
cache IS the unique-event signature cache.
"""
from __future__ import annotations

from typing import Callable, Dict, Iterable, Mapping

from repro.core.costmodel import ClusterSpec
from repro.core.profiler import AnalyticalProvider, Provider


class ProfileCache:
    """One provider (and thus one event-time cache) per cluster."""

    def __init__(self, providers: Mapping[str, Provider]):
        self.providers: Dict[str, Provider] = dict(providers)
        self._build_caches: Dict[str, object] = {}

    @classmethod
    def for_clusters(cls, clusters: Iterable[ClusterSpec],
                     provider_factory: Callable[[ClusterSpec], Provider]
                     = AnalyticalProvider) -> "ProfileCache":
        return cls({c.name: provider_factory(c) for c in clusters})

    @classmethod
    def from_provider(cls, provider: Provider) -> "ProfileCache":
        return cls({provider.cluster.name: provider})

    def provider(self, cluster: ClusterSpec) -> Provider:
        return self.providers[cluster.name]

    def build_cache(self, cluster: ClusterSpec):
        """Per-cluster :class:`repro.validate.build_cache.BuildCache`
        bound to that cluster's provider — the positions/build/engine
        dedup layer the mega-batch search path compiles from. Persists
        with this ProfileCache, so repeat searches reuse engines (and
        profile nothing). Imported lazily: repro.validate pulls in the
        sweep stack, which search-only callers don't need."""
        bc = self._build_caches.get(cluster.name)
        if bc is None:
            from repro.validate.build_cache import BuildCache
            bc = BuildCache(self.provider(cluster))
            self._build_caches[cluster.name] = bc
        return bc

    @property
    def clusters(self) -> list:
        return [p.cluster for p in self.providers.values()]

    # ---- aggregate accounting across clusters ----
    @property
    def evaluations(self) -> int:
        return sum(p.stats.evaluations for p in self.providers.values())

    @property
    def hits(self) -> int:
        return sum(p.stats.hits for p in self.providers.values())

    @property
    def unique_events(self) -> int:
        return sum(len(p._cache) for p in self.providers.values())

    def reset_stats(self) -> None:
        for p in self.providers.values():
            p.stats.reset()

    def snapshot(self) -> Dict[str, float]:
        lookups = self.evaluations + self.hits
        return {
            "unique_events": self.unique_events,
            "evaluations": self.evaluations,
            "hits": self.hits,
            "hit_rate": self.hits / lookups if lookups else 0.0,
        }
