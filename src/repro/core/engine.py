"""Event-flow timeline engine (paper §4.3, Algorithm 1).

Replaces the seed's O((dp·pp)²·tasks) polling scheduler with a
dependency-driven ready-queue: a device becomes *enabled* the moment the
head task of its schedule has all inputs known, and enabled devices are
popped from a heap keyed on ``max(device_free, input_arrival)`` — the
paper's ``first_available`` placement rule, executed exactly once per
task instead of rediscovered by rescanning every device queue.

Structure exploited (the paper's "leverage the hierarchy" claim, plus
Alpa-style replica reuse):

* **MP**    — all mp ranks of a pipeline device run the same activities;
  they are materialized by replication, never simulated.
* **DP**    — replicas only interact at the gradient sync. With zero
  noise (``jitter == straggler == clock == 0``, the predict path) every
  replica's pipeline timeline is identical, so ONE canonical replica is
  simulated and the rest are replicated analytically: scheduling work is
  O(pp·m·vpp), independent of dp.
* **Noise** — the replay oracle draws all per-instance jitter factors
  vectorized per (replica × microbatch × event) batch up front; the
  inner scheduling loop never touches the RNG.

Replay-oracle modeling fixes vs the seed polling scheduler:

* **Clock skew** is one constant offset per (replica, device, mp rank)
  per run — the seed drew an independent offset per *activity*, which
  is profiling noise, not clock skew.
* **The DP gradient all-reduce is synchronizing**: it completes when the
  slowest participant does. Durations are drawn per replica and the
  *maximum* becomes the common end time — the seed let each replica
  exit the blocking collective at its own independently-jittered time.

RNG draw order (fixed; documented so seeds stay meaningful):
straggler speeds → per-position fwd/bwd event factors → p2p factors →
(decode only: feedback-p2p factors) → DP-sync factors → optimizer
factors → clock offsets. Train runs never reach the decode draw, so
pre-scenario seeds reproduce bit-identically.

Scenario generalization: the engine is scenario-keyed. ``TrainStep``
is the historical fwd+bwd pipeline (bit-identical). Serving scenarios
(``Prefill``/``Decode``) run a forward-only schedule without gradient
sync or optimizer; ``Decode`` additionally threads each autoregressive
step's token feedback from the last stage back to stage 0 and applies
per-step arrival floors (continuous batching) through the same
dependency recurrence.
"""
from __future__ import annotations

import heapq
from collections import deque
from math import isnan
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.events import Event, Stage, Strategy
from repro.core.profiler import Provider
from repro.core.scenario import TRAIN, Scenario
from repro.core.schedules import build_schedule, forward_only
from repro.core.timeline import (Activity, LazyTimeline, Timeline,
                                 TimelineBatch)

_MIN_JITTER_FACTOR = 0.05       # clamp: an event never runs 20x faster


def _jittered(base: np.ndarray, rng, sigma: float) -> np.ndarray:
    """base * clamp(1 + sigma*N(0,1)), elementwise, vectorized."""
    f = np.maximum(_MIN_JITTER_FACTOR,
                   1.0 + sigma * rng.standard_normal(base.shape))
    return base * f


class EngineBuild:
    """Schedule-independent precomputation of an engine build.

    Everything here depends only on (stages, strategy *modulo schedule
    and microbatch count*, provider): per-position event means, p2p
    boundary means and the DP-level sync/optimizer means. The pipeline
    schedule only reorders tasks over this structure, so one build is
    shared by every same-vpp schedule of a (model, strategy) pair —
    gpipe/1f1b/pipedream always; interleaved too unless its vpp=2
    changes the position structure — the reuse
    ``repro.validate.BuildCache`` exploits (verified bit-identical in
    ``tests/test_sweep_scale.py``).

    ``with_dp_sync=None`` (the cache's mode) precomputes the gradient
    sync means whenever ``dp > 1`` so a later non-pipedream engine can
    share a build first made for pipedream; passing the engine's actual
    sync flag reproduces the historical lazy behavior exactly.

    ``scenario`` keys the build (stored *stripped* — modulo decode step
    count / arrivals, which are schedule-level): serving builds skip the
    gradient-sync and optimizer means entirely; decode builds add the
    token-feedback p2p mean. Class-level defaults below double as the
    upgrade path for builds unpickled from pre-scenario stores.
    """

    # unpickle compat: pre-scenario store pickles lack these attributes
    scenario: Scenario = TRAIN
    fb_base: float = 0.0

    def __init__(self, stages: Sequence[Stage], strat: Strategy,
                 provider: Provider,
                 with_dp_sync: Optional[bool] = None,
                 scenario: Scenario = TRAIN):
        self.stages = list(stages)
        cluster = provider.cluster
        pp, vpp = strat.pp, strat.vpp
        self.n_pos = len(self.stages)
        self.cache_version = provider.cache_version
        self.scenario = scenario.stripped()

        # ---- per-position event means (profiled once, reused) ----
        # Python-float sequential sums keep the predict path bit-identical
        # with the historical scheduler (which summed draw-by-draw).
        self.fwd_event_means: List[np.ndarray] = []
        self.bwd_event_means: List[np.ndarray] = []
        self.fwd_base: List[float] = []
        self.bwd_base: List[float] = []
        for st in self.stages:
            fm = [provider.time(e) for e in st.fwd.events]
            bm = [provider.time(e) for e in st.bwd.events]
            self.fwd_event_means.append(np.asarray(fm))
            self.bwd_event_means.append(np.asarray(bm))
            self.fwd_base.append(sum(fm))
            self.bwd_base.append(sum(bm))

        # p2p mean per boundary (identical fwd/bwd: same structural event)
        span = strat.mp + 1
        scope = "intra" if span <= cluster.devices_per_island else "inter"
        self.p2p_base = [
            provider.time(Event(kind="p2p", name=f"p2p:pos{p}",
                                nbytes=self.stages[p].boundary_act_bytes,
                                scope=scope))
            for p in range(self.n_pos)]

        # ---- DP-level event means per pipeline device ----
        chip = cluster.chip
        dp = strat.dp
        train = self.scenario.is_train
        want_sync = (dp > 1 if with_dp_sync is None else with_dp_sync)
        want_sync = want_sync and train      # serving: no gradient sync
        self.ar_base: List[float] = []
        self.opt_base: List[float] = []
        if not train:
            # forward-only: no gradient sync, no optimizer step
            self.ar_base = [0.0] * pp
            self.opt_base = [0.0] * pp
            self.fb_base = 0.0
            if self.scenario.kind == "decode" and self.stages:
                fb_bytes = getattr(self.stages[-1], "feedback_bytes", 0.0)
                span = strat.mp * strat.pp   # last stage back to stage 0
                fscope = ("intra" if span <= cluster.devices_per_island
                          else "inter")
                self.fb_base = provider.time(Event(
                    kind="p2p", name="p2p:fb", nbytes=fb_bytes,
                    scope=fscope))
            return
        for d in range(pp):
            pos_list = [c * pp + d for c in range(vpp)
                        if c * pp + d < self.n_pos]
            pbytes = (sum(self.stages[p].param_bytes for p in pos_list)
                      / max(1, strat.mp))
            pbytes *= strat.grad_compress      # int8 compression what-if
            ar = 0.0
            if want_sync:
                gspan = dp * pp * strat.mp
                gscope = ("intra" if gspan <= cluster.devices_per_island
                          else "inter")
                if strat.zero1:
                    ar = (provider.time(Event(
                        kind="collective", name=f"dp_rs:d{d}",
                        coll_op="reduce_scatter", nbytes=pbytes,
                        n_dev=dp, scope=gscope))
                        + provider.time(Event(
                            kind="collective", name=f"dp_ag:d{d}",
                            coll_op="all_gather", nbytes=pbytes,
                            n_dev=dp, scope=gscope)))
                else:
                    ar = provider.time(Event(
                        kind="collective", name=f"dp_ar:d{d}",
                        coll_op="all_reduce", nbytes=pbytes,
                        n_dev=dp, scope=gscope))
            self.ar_base.append(ar)
            # AdamW: streams fp32 master params + m + v (~6 passes of 2x)
            opt_bytes = pbytes * (1.0 / dp if strat.zero1 else 1.0)
            self.opt_base.append(6.0 * opt_bytes * 2 / chip.hbm_bw)


class EventFlowEngine:
    """One (stages × strategy × provider) simulation context.

    Build once, then ``run()`` any number of predict / replay variants —
    event means, schedules, task metadata and activity names are all
    precomputed here and shared across runs. Pass a precomputed
    ``build`` (:class:`EngineBuild`) to share the schedule-independent
    event-mean precomputation across engines that differ only in
    pipeline schedule / microbatch count.
    """

    def __init__(self, stages: Sequence[Stage], strat: Strategy,
                 provider: Provider, build: Optional[EngineBuild] = None,
                 scenario: Optional[Scenario] = None,
                 verify: Optional[bool] = None):
        self.strat = strat
        self.provider = provider
        if scenario is None:
            scenario = (getattr(build, "scenario", TRAIN)
                        if build is not None else TRAIN)
        self.scenario = scenario
        self._decode = scenario.kind == "decode"
        if not scenario.is_train and strat.vpp != 1:
            raise ValueError(
                f"scenario {scenario.label()!r} supports vpp=1 only")
        pp, vpp = strat.pp, strat.vpp
        m = scenario.task_count(strat)
        self.m = m
        dp = strat.dp
        self.sync = (dp > 1 and strat.schedule != "pipedream"
                     and scenario.is_train)
        self.has_opt = scenario.is_train
        if build is None:
            build = EngineBuild(stages, strat, provider,
                                with_dp_sync=self.sync, scenario=scenario)
        elif (len(build.stages) != len(stages)
              or any(a is not b for a, b in zip(build.stages, stages))):
            # a build for other stages would silently simulate the
            # wrong model — the engine reads ONLY build.stages
            raise ValueError("build was precomputed for different "
                             "stages than the ones passed")
        elif getattr(build, "scenario", TRAIN) != scenario.stripped():
            raise ValueError(
                f"build was precomputed for scenario "
                f"{getattr(build, 'scenario', TRAIN).label()!r}, engine "
                f"wants {scenario.stripped().label()!r}")
        self.build = build
        self.stages = build.stages
        self.n_pos = build.n_pos
        self.cache_version = build.cache_version
        self.fwd_event_means = build.fwd_event_means
        self.bwd_event_means = build.bwd_event_means
        self.fwd_base = build.fwd_base
        self.bwd_base = build.bwd_base
        self.p2p_base = build.p2p_base
        # non-syncing engines read zeros even when the shared build
        # precomputed the (unused) sync means
        self.ar_base = (build.ar_base if self.sync
                        else [0.0] * pp)
        self.opt_base = build.opt_base
        self.fb_base = getattr(build, "fb_base", 0.0)
        # decode arrival floors, padded to one entry per step
        arrivals = list(getattr(scenario, "arrivals", ()))[:m]
        self.arrival: List[float] = arrivals + [0.0] * (m - len(arrivals))

        # ---- schedule task lists as flat per-device metadata ----
        sched = (build_schedule(strat.schedule, pp, m, vpp)
                 if scenario.is_train else forward_only(pp, m))
        self.task_isf: List[List[bool]] = []
        self.task_pos: List[List[int]] = []
        self.task_micro: List[List[int]] = []
        self.task_name: List[List[str]] = []
        self.task_p2p_name: List[List[Optional[str]]] = []
        for d in range(pp):
            isf = [t.phase == "F" for t in sched[d]]
            pos = [t.chunk * pp + d for t in sched[d]]
            mic = [t.micro for t in sched[d]]
            self.task_isf.append(isf)
            self.task_pos.append(pos)
            self.task_micro.append(mic)
            self.task_name.append(
                [f"{'F' if f else 'B'}:s{p}:m{i}"
                 for f, p, i in zip(isf, pos, mic)])
            # boundary sends carry the SENDING task's position in both
            # name and stage (matches the historical activity labels)
            p2p = []
            for f, p, i in zip(isf, pos, mic):
                if f and p < self.n_pos - 1:
                    p2p.append(f"P2P:f:s{p}:m{i}")
                elif f and self._decode:
                    # last stage feeds sampled tokens back to stage 0
                    p2p.append(f"P2P:fb:m{i}")
                elif not f and p > 0:
                    p2p.append(f"P2P:b:s{p}:m{i}")
                else:
                    p2p.append(None)
            self.task_p2p_name.append(p2p)
        self.total_tasks = sum(len(t) for t in self.task_isf)
        self._topo: Optional[List[Tuple[int, int]]] = None
        # bounded FIFO: sweeps alternate two keys (predict + replay);
        # the cap keeps long-lived cached engines from pinning one
        # TimelineBatch per seed set ever requested
        self._batch_memo: dict = {}

        # construction-time static verification (repro.analyze):
        # verify=None defers to the REPRO_VERIFY env var — on in
        # tests/CI, off on hot paths so predict/search throughput pays
        # nothing. Lazy import: the analyze package is only loaded
        # when verification is actually requested.
        from repro.analyze.findings import default_verify
        if default_verify(verify):
            from repro.analyze.findings import raise_on_findings
            from repro.analyze.graph import verify_engine
            raise_on_findings(verify_engine(self))

    _BATCH_MEMO_MAX = 8

    # ------------------------------------------------------------------
    # noise sampling (vectorized; fixed draw order)
    # ------------------------------------------------------------------

    def _sample(self, dp: int, rng, jitter: float, straggler: float,
                clock: float, speed_scale=None):
        """All per-run random state, drawn up front.

        Returns (speed(dp,pp), dur_f, dur_b, p2p_f, p2p_b, fb, ar, opt,
        off) where dur_* are (dp, n_pos, m), fb is (dp, m) — the decode
        token-feedback p2p, zeros otherwise — ar/opt are (dp, pp) and
        off is (dp, pp, mp). The fb draw happens only for decode
        engines, so train RNG consumption is unchanged.

        ``speed_scale`` is a deterministic (dp, pp) duration multiplier
        (a :meth:`repro.core.perturb.Perturbation.speed_grid`) composed
        onto the stochastic straggler plane AFTER all draws — it never
        touches the RNG, so seeded replays stay lane-comparable with
        and without a perturbation, and ``None`` leaves every code
        path byte-identical.
        """
        pp, m, mp = self.strat.pp, self.m, self.strat.mp
        n_pos = self.n_pos

        speed = np.ones((dp, pp))
        if rng is not None and straggler > 0:
            speed = 1.0 + straggler * np.abs(rng.standard_normal((dp, pp)))
        if speed_scale is not None:
            speed = speed * speed_scale

        dur_f = np.empty((dp, n_pos, m))
        dur_b = np.empty((dp, n_pos, m))
        p2p_f = np.zeros((dp, n_pos, m))
        p2p_b = np.zeros((dp, n_pos, m))
        draw_jitter = rng is not None and jitter > 0
        for p in range(n_pos):
            dev = p % pp
            if draw_jitter:
                fm, bm = self.fwd_event_means[p], self.bwd_event_means[p]
                fdur = (_jittered(np.broadcast_to(fm, (dp, m, len(fm))),
                                  rng, jitter).sum(-1)
                        if len(fm) else np.zeros((dp, m)))
                bdur = (_jittered(np.broadcast_to(bm, (dp, m, len(bm))),
                                  rng, jitter).sum(-1)
                        if len(bm) else np.zeros((dp, m)))
            else:
                fdur = np.full((dp, m), self.fwd_base[p])
                bdur = np.full((dp, m), self.bwd_base[p])
            dur_f[:, p] = fdur * speed[:, dev, None]
            dur_b[:, p] = bdur * speed[:, dev, None]
        for p in range(n_pos - 1):
            # forward send pos -> pos+1 and backward send pos+1 -> pos both
            # move stage-p boundary bytes; each is drawn (and straggled) on
            # its SENDING device.
            base = np.full((dp, m), self.p2p_base[p])
            ptf = _jittered(base, rng, jitter) if draw_jitter else base
            ptb = _jittered(base, rng, jitter) if draw_jitter else base
            p2p_f[:, p] = ptf * speed[:, p % pp, None]
            p2p_b[:, p] = ptb * speed[:, (p + 1) % pp, None]

        fb = np.zeros((dp, m))
        if self._decode:
            fbase = np.full((dp, m), self.fb_base)
            fb = _jittered(fbase, rng, jitter) if draw_jitter else fbase
            fb = fb * speed[:, (n_pos - 1) % pp, None]

        ar = np.asarray(self.ar_base)[None, :] * np.ones((dp, 1))
        opt = np.asarray(self.opt_base)[None, :] * np.ones((dp, 1))
        if draw_jitter:
            ar = _jittered(ar, rng, jitter)
            opt = _jittered(opt, rng, jitter)
        ar *= speed
        opt *= speed

        off = np.zeros((dp, pp, mp))
        if rng is not None and clock > 0:
            off = clock * rng.standard_normal((dp, pp, mp))
        return speed, dur_f, dur_b, p2p_f, p2p_b, fb, ar, opt, off

    # ------------------------------------------------------------------
    # single-replica pipeline simulation (ready-queue over arrays)
    # ------------------------------------------------------------------

    def _simulate_replica(self, dur_f, dur_b, p2p_f, p2p_b, fb=None):
        """List-schedule one DP replica's pipeline.

        dur/p2p: (n_pos, m) duration lookups for THIS replica; fb: (m,)
        decode token-feedback p2p durations (None for train/prefill).
        Returns (starts, ends, p2p_ends, free) — per-device lists aligned
        with the task lists; p2p_ends entries are None for tasks with no
        boundary send.
        """
        pp, n_pos = self.strat.pp, self.n_pos
        decode = self._decode
        arrival = self.arrival
        nan = float("nan")
        f_end = [[nan] * self.m for _ in range(n_pos)]
        arr_f = [[nan] * self.m for _ in range(n_pos)]
        arr_b = [[nan] * self.m for _ in range(n_pos)]
        fb_arr = [nan] * self.m         # decode: step feedback arrivals
        dur_f = dur_f.tolist()
        dur_b = dur_b.tolist()
        p2p_f = p2p_f.tolist()
        p2p_b = p2p_b.tolist()
        fb = fb.tolist() if fb is not None else None

        free = [0.0] * pp
        ptr = [0] * pp
        n_tasks = [len(t) for t in self.task_isf]
        starts = [[] for _ in range(pp)]
        ends = [[] for _ in range(pp)]
        p2p_ends: List[List[Optional[float]]] = [[] for _ in range(pp)]

        heap: List[Tuple[float, int]] = []
        enabled = [False] * pp

        def try_enable(d: int) -> None:
            if enabled[d] or ptr[d] >= n_tasks[d]:
                return
            i = ptr[d]
            pos, mic = self.task_pos[d][i], self.task_micro[d][i]
            if self.task_isf[d][i]:
                if pos != 0:
                    ready = arr_f[pos][mic]
                elif not decode:
                    ready = 0.0
                elif mic == 0:
                    ready = arrival[0]
                else:
                    fa = fb_arr[mic - 1]
                    ready = fa if isnan(fa) else max(fa, arrival[mic])
            else:
                ready = f_end[pos][mic]
                if pos < n_pos - 1 and not isnan(ready):
                    ab = arr_b[pos][mic]
                    ready = ab if isnan(ab) else max(ready, ab)
            if not isnan(ready):
                enabled[d] = True
                heapq.heappush(heap, (max(free[d], ready), d))

        for d in range(pp):
            try_enable(d)

        done = 0
        while heap:
            start, d = heapq.heappop(heap)
            enabled[d] = False
            i = ptr[d]
            pos, mic = self.task_pos[d][i], self.task_micro[d][i]
            if self.task_isf[d][i]:
                end = start + dur_f[pos][mic]
                f_end[pos][mic] = end
                if pos < n_pos - 1:
                    t_arr = end + p2p_f[pos][mic]
                    arr_f[pos + 1][mic] = t_arr
                    p2p_ends[d].append(t_arr)
                    try_enable((pos + 1) % pp)
                elif decode:
                    # token feedback to stage 0's next step; when d == 0
                    # (pp == 1) the trailing try_enable(d) below sees it
                    # after ptr advances
                    t_arr = end + fb[mic]
                    fb_arr[mic] = t_arr
                    p2p_ends[d].append(t_arr)
                    if d != 0:
                        try_enable(0)
                else:
                    p2p_ends[d].append(None)
            else:
                end = start + dur_b[pos][mic]
                if pos > 0:
                    t_arr = end + p2p_b[pos - 1][mic]
                    arr_b[pos - 1][mic] = t_arr
                    p2p_ends[d].append(t_arr)
                    try_enable((pos - 1) % pp)
                else:
                    p2p_ends[d].append(None)
            starts[d].append(start)
            ends[d].append(end)
            free[d] = end
            ptr[d] += 1
            done += 1
            try_enable(d)

        if done != self.total_tasks:
            raise RuntimeError(
                f"pipeline schedule deadlock: {self.strat.label()} "
                f"{self.strat.schedule} done={done}/{self.total_tasks}")
        return starts, ends, p2p_ends, free

    # ------------------------------------------------------------------
    # activity materialization (shared by run() and run_batched lanes)
    # ------------------------------------------------------------------

    def _materialize(self, dev_times, ar_span, opt_span, off
                     ) -> List[Activity]:
        """Build one run's Activity list from its timing accessors.

        ``dev_times(r, d)`` -> (starts, ends, p2p_ends) sequences
        aligned with device ``d``'s task list (p2p entries are read
        only for tasks that have a boundary send); ``ar_span(d)`` ->
        (start, end) of the gradient sync (read only when syncing);
        ``opt_span(r, d)`` -> (t0, t1); ``off[r, d, j]`` clock
        offsets. Sequential and batched runs feed the same builder, so
        activity labeling can never diverge between the two paths.
        """
        acts: List[Activity] = []
        add = acts.append
        pp, dp, mp = self.strat.pp, self.strat.dp, self.strat.mp
        for r in range(dp):
            for d in range(pp):
                names = self.task_name[d]
                p2p_names = self.task_p2p_name[d]
                isf = self.task_isf[d]
                pos_l = self.task_pos[d]
                mic_l = self.task_micro[d]
                st_l, en_l, pe_l = dev_times(r, d)
                base = (r * pp + d) * mp
                for j in range(mp):
                    o = off[r, d, j]
                    dev = base + j
                    for i in range(len(names)):
                        s, e = st_l[i], en_l[i]
                        add(Activity(device=dev, name=names[i],
                                     kind="F" if isf[i] else "B",
                                     start=s + o, end=e + o,
                                     stage=pos_l[i], micro=mic_l[i]))
                        if p2p_names[i] is not None:
                            add(Activity(device=dev, name=p2p_names[i],
                                         kind="P2P", start=e + o,
                                         end=pe_l[i] + o, stage=pos_l[i],
                                         micro=mic_l[i]))
                    if self.sync:
                        a0, a1 = ar_span(d)
                        add(Activity(device=dev, name=f"AR:d{d}",
                                     kind="AR", start=a0 + o, end=a1 + o,
                                     stage=d))
                    if self.has_opt:
                        t0, t1 = opt_span(r, d)
                        add(Activity(device=dev, name=f"OPT:d{d}",
                                     kind="OPT", start=t0 + o, end=t1 + o,
                                     stage=d))
        return acts

    # ------------------------------------------------------------------
    # full run
    # ------------------------------------------------------------------

    def _perturb_grid(self, perturb):
        """Resolve a :class:`repro.core.perturb.Perturbation` to its
        (dp, pp) multiplier plane (duck-typed — the engine stays
        import-free of the perturb module). The engine models only the
        straggler multipliers of ONE step; fault splicing across steps
        lives in ``DistSim.simulate(perturb=...)``."""
        if perturb is None:
            return None
        if getattr(perturb, "faults", ()):
            raise ValueError(
                "the engine evaluates one step; fault recovery is "
                "spliced at the run level — use "
                "DistSim.simulate(perturb=...)")
        return perturb.speed_grid(self.strat)

    def run(self, jitter_sigma: float = 0.0, straggler_sigma: float = 0.0,
            clock_sigma: float = 0.0, seed: Optional[int] = None,
            perturb=None) -> Timeline:
        strat = self.strat
        pp, dp, mp = strat.pp, strat.dp, strat.mp
        noisy = (jitter_sigma > 0 or straggler_sigma > 0 or clock_sigma > 0)
        rng = (np.random.RandomState(seed)
               if seed is not None and noisy else None)
        grid = self._perturb_grid(perturb)
        _, dur_f, dur_b, p2p_f, p2p_b, fb, ar, opt, off = self._sample(
            dp, rng, jitter_sigma, straggler_sigma, clock_sigma,
            speed_scale=grid)

        # DP replicas are independent until the gradient sync; with zero
        # noise they are identical — simulate one, replicate analytically
        # (a perturbation grid varies per replica, so it simulates all).
        n_sim = dp if (rng is not None or grid is not None) else 1
        reps = [self._simulate_replica(dur_f[r], dur_b[r],
                                       p2p_f[r], p2p_b[r],
                                       fb[r] if self._decode else None)
                for r in range(n_sim)]

        # ---- DP level: gradient sync + optimizer ----
        # A blocking all-reduce starts when the last participant arrives
        # and ends when the slowest draw completes — common to ALL
        # replicas (the synchronizing-collective fix).
        ar_start = [0.0] * pp
        ar_end = [0.0] * pp
        if self.sync:
            for d in range(pp):
                ar_start[d] = max(reps[r % n_sim][3][d] for r in range(dp))
                ar_end[d] = ar_start[d] + max(ar[r, d] for r in range(dp))
        opt_span = [[None] * pp for _ in range(dp)]
        for r in range(dp):
            freer = reps[r % n_sim][3]
            for d in range(pp):
                t0 = ar_end[d] if self.sync else freer[d]
                opt_span[r][d] = (t0, t0 + float(opt[r, d]))

        # ---- aggregate stats from the arrays (no Activity objects) ----
        # pipeline-level busy / latest-end per simulated replica & device
        pipe_busy = [[0.0] * pp for _ in range(n_sim)]
        pipe_last = [[0.0] * pp for _ in range(n_sim)]
        for s in range(n_sim):
            starts, ends, p2p_ends, _ = reps[s]
            for d in range(pp):
                b = 0.0
                last = 0.0
                for st, en in zip(starts[d], ends[d]):
                    b += en - st
                    if en > last:
                        last = en
                for pe in p2p_ends[d]:
                    if pe is not None and pe > last:
                        last = pe
                pipe_busy[s][d] = b
                pipe_last[s][d] = last

        busy: List[float] = [0.0] * (dp * pp * mp)
        batch_time = 0.0
        for r in range(dp):
            s = r % n_sim
            for d in range(pp):
                b = pipe_busy[s][d]
                if self.sync:
                    b += ar_end[d] - ar_start[d]
                t0, t1 = opt_span[r][d]
                b += t1 - t0
                last = max(pipe_last[s][d], t1)
                base = (r * pp + d) * mp
                for j in range(mp):
                    busy[base + j] = b
                    end_j = last + off[r, d, j]
                    if end_j > batch_time:
                        batch_time = end_j

        def materialize() -> List[Activity]:
            def dev_times(r, d):
                starts, ends, p2p_ends, _ = reps[r % n_sim]
                return starts[d], ends[d], p2p_ends[d]
            return self._materialize(
                dev_times, lambda d: (ar_start[d], ar_end[d]),
                lambda r, d: opt_span[r][d], off)

        return LazyTimeline(n_devices=dp * pp * mp, builder=materialize,
                            batch_time=batch_time, busy=busy)

    # ------------------------------------------------------------------
    # batched multi-seed replay (one dependency pass, all seeds at once)
    # ------------------------------------------------------------------

    def _topo_order(self) -> List[Tuple[int, int]]:
        """One duration-free dependency-resolution pass.

        The task dependency DAG (device serialization + boundary
        arrivals) does not depend on event durations, so a single
        topological order of ``(device, task_index)`` is valid for
        EVERY seed and replica: the ready-queue's enabling conditions
        are replayed with known/unknown flags instead of times, and the
        pop order is recorded. ``run_batched`` then evaluates the
        timing recurrences along this order with all lanes stacked.
        """
        if self._topo is not None:
            return self._topo
        pp, n_pos, m = self.strat.pp, self.n_pos, self.m
        decode = self._decode
        f_known = [[False] * m for _ in range(n_pos)]
        af_known = [[False] * m for _ in range(n_pos)]
        ab_known = [[False] * m for _ in range(n_pos)]
        fb_known = [False] * m
        ptr = [0] * pp
        n_tasks = [len(t) for t in self.task_isf]
        order: List[Tuple[int, int]] = []
        queue: deque = deque()
        enabled = [False] * pp

        def try_enable(d: int) -> None:
            if enabled[d] or ptr[d] >= n_tasks[d]:
                return
            i = ptr[d]
            pos, mic = self.task_pos[d][i], self.task_micro[d][i]
            if self.task_isf[d][i]:
                if pos == 0:
                    ok = not decode or mic == 0 or fb_known[mic - 1]
                else:
                    ok = af_known[pos][mic]
            else:
                ok = f_known[pos][mic] and (pos == n_pos - 1
                                            or ab_known[pos][mic])
            if ok:
                enabled[d] = True
                queue.append(d)

        for d in range(pp):
            try_enable(d)
        while queue:
            d = queue.popleft()
            enabled[d] = False
            i = ptr[d]
            pos, mic = self.task_pos[d][i], self.task_micro[d][i]
            if self.task_isf[d][i]:
                f_known[pos][mic] = True
                if pos < n_pos - 1:
                    af_known[pos + 1][mic] = True
                    try_enable((pos + 1) % pp)
                elif decode:
                    fb_known[mic] = True
                    if d != 0:
                        try_enable(0)
            else:
                if pos > 0:
                    ab_known[pos - 1][mic] = True
                    try_enable((pos - 1) % pp)
            order.append((d, i))
            ptr[d] += 1
            try_enable(d)

        if len(order) != self.total_tasks:
            raise RuntimeError(
                f"pipeline schedule deadlock: {self.strat.label()} "
                f"{self.strat.schedule} done={len(order)}/"
                f"{self.total_tasks}")
        self._topo = order
        return order

    def topo_order(self) -> List[Tuple[int, int]]:
        """Public accessor for the cached duration-free topological
        order — the contract :class:`repro.core.megabatch.MegaBatch`
        compiles against (step j of the array program evaluates the
        j-th entry of this order for every candidate)."""
        return self._topo_order()

    def run_batched(self, seeds: Optional[Sequence[Optional[int]]] = None,
                    jitter_sigma: float = 0.0,
                    straggler_sigma: float = 0.0,
                    clock_sigma: float = 0.0,
                    perturb=None) -> TimelineBatch:
        """All S seeds' replays in one pass, bit-identical per seed to
        sequential ``run(seed=s)`` calls.

        Per-seed noise is drawn exactly as ``run`` draws it (one
        RandomState per seed, same consumption order), stacked, and the
        scheduling recurrences are evaluated ONCE along the shared
        :meth:`_topo_order` with every (seed × replica) lane as a NumPy
        vector — the Python dependency walk no longer scales with S or
        dp. ``seeds=None`` is the predict lane (S=1, zero noise).
        ``perturb`` applies a deterministic straggler multiplier plane
        to every lane (see :meth:`_perturb_grid`); ``None`` is the
        byte-identical unperturbed path. Returns a
        :class:`TimelineBatch`; no ``Activity`` objects are built.
        """
        strat = self.strat
        pp, dp, mp = strat.pp, strat.dp, strat.mp
        m, n_pos = self.m, self.n_pos
        lane_seeds: List[Optional[int]] = ([None] if seeds is None
                                           else list(seeds))
        if not lane_seeds:
            raise ValueError("run_batched needs at least one seed")
        S = len(lane_seeds)
        noisy = (jitter_sigma > 0 or straggler_sigma > 0
                 or clock_sigma > 0)
        grid = self._perturb_grid(perturb)
        # any batched run is a pure function of (build, seeds, sigmas,
        # perturb) — memoized so cached engines (validate.BuildCache
        # reuse across sweeps) skip the draw + recurrence pass entirely
        # on a repeat. One entry per distinct combination actually
        # requested; sweeps use one.
        memo_key = (tuple(lane_seeds), jitter_sigma, straggler_sigma,
                    clock_sigma, perturb)
        hit = self._batch_memo.get(memo_key)
        if hit is not None:
            return hit

        samples = []
        any_rng = False
        for s in lane_seeds:
            rng = (np.random.RandomState(s)
                   if s is not None and noisy else None)
            any_rng = any_rng or rng is not None
            samples.append(self._sample(dp, rng, jitter_sigma,
                                        straggler_sigma, clock_sigma,
                                        speed_scale=grid))
        # A zero-noise lane has identical replicas, so simulating dp of
        # them (when other lanes are noisy) reproduces run()'s analytic
        # replication bit-for-bit. A perturbation grid varies per
        # replica, so it forces the full simulation too.
        n_sim = dp if (any_rng or grid is not None) else 1
        R = S * n_sim

        def lanes(k: int) -> np.ndarray:
            """samples[:][k] stacked and flattened to (R, ...)."""
            a = np.stack([smp[k] for smp in samples])
            return (a.reshape((R,) + a.shape[2:]) if n_sim == dp
                    else a[:, 0])

        durf_l, durb_l = lanes(1), lanes(2)         # (R, n_pos, m)
        p2pf_l, p2pb_l = lanes(3), lanes(4)
        fb_l = lanes(5)                             # (R, m)
        ar = np.stack([smp[6] for smp in samples])  # (S, dp, pp)
        opt = np.stack([smp[7] for smp in samples])
        off = np.stack([smp[8] for smp in samples])  # (S, dp, pp, mp)

        # ---- vectorized recurrence evaluation along the topo order ----
        decode = self._decode
        arrival = self.arrival
        n_tasks = [len(t) for t in self.task_isf]
        f_end = np.zeros((R, n_pos, m))
        arr_f = np.zeros((R, n_pos, m))
        arr_b = np.zeros((R, n_pos, m))
        fb_end = np.zeros((R, m))
        free = np.zeros((R, pp))
        starts = [np.zeros((R, n)) for n in n_tasks]
        ends = [np.zeros((R, n)) for n in n_tasks]
        p2p_end = [np.zeros((R, n)) for n in n_tasks]
        busy_pipe = np.zeros((R, pp))
        last_pipe = np.zeros((R, pp))

        for d, i in self._topo_order():
            pos, mic = self.task_pos[d][i], self.task_micro[d][i]
            fr = free[:, d]                # view — read-only until below
            if self.task_isf[d][i]:
                if pos != 0:
                    start = np.maximum(fr, arr_f[:, pos, mic])
                elif not decode:
                    start = fr
                elif mic == 0:
                    start = np.maximum(fr, arrival[0])
                else:
                    # same max grouping as the sequential heap key:
                    # max(free, max(feedback, arrival)) — exact either way
                    start = np.maximum(
                        fr, np.maximum(fb_end[:, mic - 1], arrival[mic]))
                end = start + durf_l[:, pos, mic]
                f_end[:, pos, mic] = end
                if pos < n_pos - 1:
                    arr = end + p2pf_l[:, pos, mic]
                    arr_f[:, pos + 1, mic] = arr
                    p2p_end[d][:, i] = arr
                    last_pipe[:, d] = np.maximum(last_pipe[:, d], arr)
                elif decode:
                    arr = end + fb_l[:, mic]
                    fb_end[:, mic] = arr
                    p2p_end[d][:, i] = arr
                    last_pipe[:, d] = np.maximum(last_pipe[:, d], arr)
            else:
                ready = f_end[:, pos, mic]
                if pos < n_pos - 1:
                    ready = np.maximum(ready, arr_b[:, pos, mic])
                start = np.maximum(fr, ready)
                end = start + durb_l[:, pos, mic]
                if pos > 0:
                    arr = end + p2pb_l[:, pos - 1, mic]
                    arr_b[:, pos - 1, mic] = arr
                    p2p_end[d][:, i] = arr
                    last_pipe[:, d] = np.maximum(last_pipe[:, d], arr)
            starts[d][:, i] = start
            ends[d][:, i] = end
            busy_pipe[:, d] += end - start  # before free[:, d] aliases start
            free[:, d] = end
            last_pipe[:, d] = np.maximum(last_pipe[:, d], end)

        # ---- DP level (same fold order as run(), vectorized over S) ----
        def expand(a: np.ndarray) -> np.ndarray:
            """(S, n_sim, pp) -> (S, dp, pp) replica view (r % n_sim)."""
            a = a.reshape(S, n_sim, pp)
            return a if n_sim == dp else np.broadcast_to(a, (S, dp, pp))

        free_e = expand(free)
        busy_e = expand(busy_pipe)
        last_e = expand(last_pipe)

        ar_start = np.zeros((S, pp))
        ar_end = np.zeros((S, pp))
        if self.sync:
            ar_start = free_e.max(axis=1)
            ar_end = ar_start + ar.max(axis=1)
            opt_t0 = np.broadcast_to(ar_end[:, None, :], (S, dp, pp))
        else:
            opt_t0 = free_e
        opt_t1 = opt_t0 + opt

        busy_full = busy_e
        if self.sync:
            busy_full = busy_full + (ar_end - ar_start)[:, None, :]
        busy_full = busy_full + (opt_t1 - opt_t0)
        busy_dev = np.broadcast_to(
            busy_full[:, :, :, None], (S, dp, pp, mp)).reshape(S, -1)

        last = np.maximum(last_e, opt_t1)                # (S, dp, pp)
        end_j = last[:, :, :, None] + off                # (S, dp, pp, mp)
        batch_times = np.maximum(end_j.max(axis=(1, 2, 3)), 0.0)

        starts_r = [a.reshape(S, n_sim, -1) for a in starts]
        ends_r = [a.reshape(S, n_sim, -1) for a in ends]
        p2p_r = [a.reshape(S, n_sim, -1) for a in p2p_end]

        def lane_builder(lane: int):
            def materialize() -> List[Activity]:
                def dev_times(r, d):
                    rr = r % n_sim
                    return (starts_r[d][lane, rr], ends_r[d][lane, rr],
                            p2p_r[d][lane, rr])
                return self._materialize(
                    dev_times,
                    lambda d: (ar_start[lane, d], ar_end[lane, d]),
                    lambda r, d: (opt_t0[lane, r, d], opt_t1[lane, r, d]),
                    off[lane])
            return materialize

        batch = TimelineBatch(
            seeds=lane_seeds, n_devices=dp * pp * mp, dp=dp, pp=pp, mp=mp,
            n_sim=n_sim, batch_times=batch_times, busy=busy_dev,
            starts=starts_r, ends=ends_r, offsets=off,
            lane_builder=lane_builder)
        if len(self._batch_memo) >= self._BATCH_MEMO_MAX:
            self._batch_memo.pop(next(iter(self._batch_memo)))
        self._batch_memo[memo_key] = batch
        return batch
