"""Timeline-engine scaling benchmark (CI: timeline-smoke job).

Measures the event-flow engine (``repro.core.engine``) against the
historical polling scheduler (``repro.core._polling_reference``) and
records scaling: the predict path at >= 4096 devices and the replay
oracle at >= 1024 devices. A second section measures **seed scaling**:
one full validation-cell evaluation (predict + S replay seeds +
metrics, ``repro.validate.run_cell``) with the batched array-native
path vs the historical one-``run()``-per-seed loop.

Two CI gates, both exiting non-zero on breach:

* engine predict >= 10x faster than the polling scheduler at 1024
  devices;
* batched multi-seed replay >= 5x faster than the sequential replay
  loop at S=8 seeds on the 1024-device cell.

    PYTHONPATH=src python benchmarks/bench_timeline.py --smoke
    PYTHONPATH=src python benchmarks/bench_timeline.py --full
    PYTHONPATH=src python benchmarks/bench_timeline.py --out bench.json
"""
from __future__ import annotations

import argparse
import json
import sys
import time

from repro.configs.base import get_config
from repro.core import A40_CLUSTER, AnalyticalProvider, DistSim, Strategy
from repro.core._polling_reference import construct_timeline_polling
from repro.validate import run_cell
from repro.validate.sweep import ValidationCell

MODEL = "gpt2_345m"
SEQ = 128
GATE_DEVICES = 1024
GATE_SPEEDUP = 10.0
SEED_GATE_S = 8
SEED_GATE_SPEEDUP = 5.0

#: devices -> (mp, pp, dp, m); devices = mp * pp * dp
SIZES = {
    256: (4, 8, 8, 16),
    1024: (4, 8, 32, 16),
    4096: (4, 8, 128, 16),
    8192: (8, 16, 64, 16),
    16384: (8, 16, 128, 32),
}


def _best_of(fn, n=3):
    best = float("inf")
    for _ in range(n):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _polling_predict_stats(cfg, strat, gb, provider, pos):
    tl = construct_timeline_polling(cfg, strat, gb, SEQ, provider,
                                    positions=pos)
    util = tl.utilization()            # same stats DistSim.predict computes
    tl.bubble_fraction(util)
    return tl


def bench_cell(cfg, provider, devices: int, with_polling: bool,
               with_replay_polling: bool) -> dict:
    mp, pp, dp, m = SIZES[devices]
    strat = Strategy(mp=mp, pp=pp, dp=dp, microbatches=m)
    gb = dp * m
    sim = DistSim(cfg, strat, gb, SEQ, provider)
    pos = sim.positions()

    t0 = time.perf_counter()
    engine = sim.engine(pos)           # built once, cached for the runs
    build_s = time.perf_counter() - t0

    cell = {
        "devices": devices,
        "strategy": f"{strat.label()}:m{m}",
        "tasks": engine.total_tasks * dp,
        "engine_build_s": build_s,
        "engine_predict_s": _best_of(
            lambda: engine.run()),
        "engine_replay_s": _best_of(
            lambda: engine.run(jitter_sigma=0.025, seed=0)),
    }
    tl = engine.run()
    t0 = time.perf_counter()
    acts = tl.activities               # lazy -> materialize now
    cell["materialize_s"] = time.perf_counter() - t0
    cell["n_activities"] = len(acts)

    if with_polling:
        cell["polling_predict_s"] = _best_of(
            lambda: _polling_predict_stats(cfg, strat, gb, provider, pos),
            n=1)
        cell["speedup_predict"] = (cell["polling_predict_s"]
                                   / cell["engine_predict_s"])
    if with_replay_polling:
        cell["polling_replay_s"] = _best_of(
            lambda: construct_timeline_polling(
                cfg, strat, gb, SEQ, provider, jitter_sigma=0.025,
                seed=0, positions=pos),
            n=1)
        cell["speedup_replay"] = (cell["polling_replay_s"]
                                  / cell["engine_replay_s"])
    return cell


def bench_seed_scaling(provider, devices: int, s_list, baseline_s) -> list:
    """Validation-cell evaluation (predict + S replays + metrics) at
    one strategy size: batched vs sequential ``run_cell``. The
    sequential baseline is only timed for ``baseline_s`` (it is the
    slow path being replaced — 8 seeds at 1024 devices take seconds)."""
    mp, pp, dp, m = SIZES[devices]
    strat = Strategy(mp=mp, pp=pp, dp=dp, microbatches=m)
    cell = ValidationCell(MODEL, strat, global_batch=dp * m, seq=SEQ)
    run_cell(cell, provider, seeds=(0,), batched=True)   # warm caches
    rows = []
    for S in s_list:
        seeds = tuple(range(S))
        t0 = time.perf_counter()
        run_cell(cell, provider, seeds=seeds, batched=True)
        t_batched = time.perf_counter() - t0
        row = {"devices": devices, "seeds": S, "batched_s": t_batched}
        if S in baseline_s:
            t0 = time.perf_counter()
            run_cell(cell, provider, seeds=seeds, batched=False)
            row["sequential_s"] = time.perf_counter() - t0
            row["speedup"] = row["sequential_s"] / t_batched
        rows.append(row)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    mode = ap.add_mutually_exclusive_group()
    mode.add_argument("--smoke", action="store_true",
                      help="CI sizes (<= 4096 devices; the default)")
    mode.add_argument("--full", action="store_true",
                      help="scale to 16384 devices, polling to 4096")
    ap.add_argument("--out", default="timeline_bench.json",
                    help="report path ('' to skip writing)")
    args = ap.parse_args()

    sizes = ([256, 1024, 4096, 8192, 16384] if args.full
             else [256, 1024, 4096])
    polling_cap = 4096 if args.full else GATE_DEVICES

    cfg = get_config(MODEL)
    provider = AnalyticalProvider(A40_CLUSTER)
    t0 = time.perf_counter()
    cells = [bench_cell(cfg, provider, n,
                        with_polling=n <= polling_cap,
                        with_replay_polling=n <= polling_cap)
             for n in sizes]
    wall = time.perf_counter() - t0

    hdr = (f"{'devices':>8} {'tasks':>8} {'predict':>10} {'replay':>10} "
           f"{'material.':>10} {'poll-pred':>10} {'pred-x':>8} "
           f"{'repl-x':>8}")
    print(f"timeline engine scaling — {MODEL}, {A40_CLUSTER.name}, "
          f"seq={SEQ}\n\n{hdr}")
    for c in cells:
        print(f"{c['devices']:>8} {c['tasks']:>8} "
              f"{c['engine_predict_s'] * 1e3:>8.1f}ms "
              f"{c['engine_replay_s'] * 1e3:>8.1f}ms "
              f"{c['materialize_s'] * 1e3:>8.1f}ms "
              + (f"{c['polling_predict_s'] * 1e3:>8.1f}ms "
                 f"{c['speedup_predict']:>7.0f}x "
                 f"{c['speedup_replay']:>7.0f}x"
                 if "polling_predict_s" in c else f"{'—':>10} "
                 f"{'—':>8} {'—':>8}"))
    print(f"\nswept {len(cells)} sizes in {wall:.1f}s")

    # ---- seed scaling: batched multi-seed replay vs sequential loop ----
    if args.full:
        seed_plan = [(1024, (1, 2, 4, 8, 16), (1, 8, 16)),
                     (4096, (8,), (8,))]
    else:
        seed_plan = [(256, (1, 2, 4, 8), (1, 8)),
                     (1024, (8,), (8,))]
    t0 = time.perf_counter()
    seed_rows = []
    for devices, s_list, baseline_s in seed_plan:
        seed_rows.extend(bench_seed_scaling(provider, devices, s_list,
                                            baseline_s))
    seed_wall = time.perf_counter() - t0

    print(f"\nseed scaling — validation cell (predict + S replays + "
          f"metrics), batched vs sequential\n\n"
          f"{'devices':>8} {'seeds':>6} {'batched':>10} "
          f"{'sequential':>11} {'speedup':>8}")
    for r in seed_rows:
        print(f"{r['devices']:>8} {r['seeds']:>6} "
              f"{r['batched_s'] * 1e3:>8.1f}ms "
              + (f"{r['sequential_s'] * 1e3:>9.1f}ms "
                 f"{r['speedup']:>7.1f}x" if "speedup" in r
                 else f"{'—':>11} {'—':>8}"))
    print(f"\nseed scaling swept in {seed_wall:.1f}s")

    gate = next(c for c in cells if c["devices"] == GATE_DEVICES)
    seed_gate = next(r for r in seed_rows
                     if r["devices"] == GATE_DEVICES
                     and r["seeds"] == SEED_GATE_S)
    report = {
        "schema": 2,
        "model": MODEL,
        "cluster": A40_CLUSTER.name,
        "mode": "full" if args.full else "smoke",
        "gate": {"devices": GATE_DEVICES, "required_speedup": GATE_SPEEDUP,
                 "speedup_predict": gate["speedup_predict"],
                 "speedup_replay": gate["speedup_replay"]},
        "seed_gate": {"devices": GATE_DEVICES, "seeds": SEED_GATE_S,
                      "required_speedup": SEED_GATE_SPEEDUP,
                      "speedup": seed_gate["speedup"]},
        "cells": cells,
        "seed_scaling": seed_rows,
    }
    if args.out:
        with open(args.out, "w") as f:
            json.dump(report, f, indent=1)
        print(f"report written to {args.out}")

    failed = False
    if gate["speedup_predict"] < GATE_SPEEDUP:
        print(f"bench_timeline/ERROR: predict speedup "
              f"{gate['speedup_predict']:.1f}x < {GATE_SPEEDUP}x at "
              f"{GATE_DEVICES} devices", file=sys.stderr)
        failed = True
    if seed_gate["speedup"] < SEED_GATE_SPEEDUP:
        print(f"bench_timeline/ERROR: batched-replay speedup "
              f"{seed_gate['speedup']:.1f}x < {SEED_GATE_SPEEDUP}x at "
              f"S={SEED_GATE_S} seeds, {GATE_DEVICES} devices",
              file=sys.stderr)
        failed = True
    if failed:
        sys.exit(1)
    print(f"gates OK: {gate['speedup_predict']:.0f}x predict / "
          f"{gate['speedup_replay']:.0f}x replay vs polling at "
          f"{GATE_DEVICES} devices (gate: {GATE_SPEEDUP:.0f}x); "
          f"{seed_gate['speedup']:.0f}x batched replay at "
          f"S={SEED_GATE_S} seeds (gate: {SEED_GATE_SPEEDUP:.0f}x)")


if __name__ == "__main__":
    main()
