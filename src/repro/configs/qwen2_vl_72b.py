"""qwen2-vl-72b [vlm] — M-RoPE, dynamic resolution; BACKBONE only here.

80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064
[arXiv:2409.12191; hf]

Vision frontend is a STUB: input_specs() provides precomputed patch
embeddings (batch, 1024, d_model) prepended to text-token embeddings.
M-RoPE realized as standard RoPE on the flattened sequence (DESIGN.md §4).
long_500k skipped: full attention.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen2_vl_72b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab=152064,
    qkv_bias=True,
    rope_theta=1e6,
    vision_stub=True,
    shapes=("train_4k", "prefill_32k", "decode_32k"),
    source="arXiv:2409.12191; hf",
))

# number of stub patch-embedding positions prepended to the text sequence
N_PATCHES = 1024
