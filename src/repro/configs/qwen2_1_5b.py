"""qwen2-1.5b [dense] — GQA with QKV bias.

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936
[arXiv:2407.10671; hf]

long_500k skipped: pure full attention (see DESIGN.md §4).
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen2_1_5b",
    family="dense",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab=151936,
    qkv_bias=True,
    rope_theta=1e6,
    tie_embeddings=True,
    shapes=("train_4k", "prefill_32k", "decode_32k"),
    source="arXiv:2407.10671; hf",
))
