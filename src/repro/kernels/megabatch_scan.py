"""Accelerator backends for the mega-batch predict recurrence.

:class:`repro.core.megabatch.MegaBatch` compiles K candidate engines
into ``(T, K)`` step arrays; this module evaluates the step recurrence

    start[j] = max over 3 deps of (ends[dep[j]] + delay[j])
    ends[out[j]] = start[j] + dur[j]

on jax: a ``lax.scan`` over the T steps (the dependency chain is
inherently sequential; each step is a (K, 3) gather + add + row-max),
and optionally a pallas kernel that keeps the global ``ends`` vector
resident in VMEM across the sequential grid — the per-step
max/accumulate hot loop fused into one kernel launch.

These paths run in whatever precision jax is configured for (float32
by default); the numpy path in :mod:`repro.core.megabatch` remains the
bit-identical reference and the default on CPU. Import of jax is
deferred to call time so numpy-only environments can import this
module's callers freely.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

try:                              # deferred everywhere else; this flag
    import jax                    # only gates backend availability
    HAS_JAX = True
except ImportError:               # pragma: no cover - numpy-only CI env
    jax = None
    HAS_JAX = False


def accelerator_backend() -> Optional[str]:
    """'gpu' / 'tpu' when jax sees an accelerator, else None — the
    signal ``backend='auto'`` uses to leave CPU runs on numpy."""
    if not HAS_JAX:
        return None
    b = jax.default_backend()
    return b if b in ("gpu", "tpu") else None


def _require_jax() -> None:
    if not HAS_JAX:
        raise RuntimeError(
            "megabatch backend 'jax'/'pallas' requires jax; this "
            "environment has numpy only — use backend='numpy'")


def scan_steps(out: np.ndarray, dep: np.ndarray, delay: np.ndarray,
               dur: np.ndarray, n_slots: int, backend: str = "jax",
               interpret: Optional[bool] = None
               ) -> Tuple[np.ndarray, np.ndarray]:
    """Evaluate the step recurrence; returns float64 numpy
    ``(ends, starts)`` vectors of length ``n_slots`` (upcast from the
    jax dtype in use)."""
    _require_jax()
    if backend == "jax":
        ends, step_starts = _scan_jax(out, dep, delay, dur, n_slots)
    elif backend == "pallas":
        ends, step_starts = _scan_pallas(out, dep, delay, dur, n_slots,
                                         interpret=interpret)
    else:
        raise ValueError(f"unknown scan backend {backend!r}")
    ends = np.asarray(ends, dtype=np.float64)
    # scatter per-step start rows back to slot order (trash-slot rows
    # overwrite each other; their value is never read)
    starts = np.zeros(n_slots)
    starts[np.asarray(out)] = np.asarray(step_starts, dtype=np.float64)
    return ends, starts


def _scan_jax(out, dep, delay, dur, n_slots):
    import jax.numpy as jnp
    from jax import lax

    dtype = jnp.result_type(float)      # honors jax_enable_x64

    def step(ends, xs):
        o, dp_, dl, du = xs
        start = jnp.max(ends[dp_] + dl, axis=-1)
        return ends.at[o].set(start + du), start

    ends0 = jnp.zeros((n_slots,), dtype=dtype)
    xs = (jnp.asarray(out), jnp.asarray(dep),
          jnp.asarray(delay, dtype=dtype), jnp.asarray(dur, dtype=dtype))
    return jax.jit(lambda e, x: lax.scan(step, e, x))(ends0, xs)


def _scan_pallas(out, dep, delay, dur, n_slots, interpret=None):
    """Per-step max/accumulate as a pallas kernel.

    The grid iterates the T steps sequentially; ``ends``/``starts``
    use a constant index map so the same VMEM block is revisited every
    step — the scan state never round-trips to HBM between steps.
    ``interpret`` defaults to True off-TPU/GPU so the kernel is
    exercisable (and tested) on CPU.
    """
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    if interpret is None:
        interpret = accelerator_backend() is None
    T, K = out.shape
    dtype = jnp.result_type(float)

    def kernel(out_ref, dep_ref, delay_ref, dur_ref, ends_ref,
               starts_ref):
        j = pl.program_id(0)

        @pl.when(j == 0)
        def _init():
            ends_ref[...] = jnp.zeros_like(ends_ref)
            starts_ref[...] = jnp.zeros_like(starts_ref)

        ends = ends_ref[...]
        start = jnp.max(ends[dep_ref[0]] + delay_ref[0], axis=-1)
        o = out_ref[0]
        ends_ref[...] = ends.at[o].set(start + dur_ref[0])
        starts_ref[...] = starts_ref[...].at[o].set(start)

    row = lambda j: (j, 0)                          # noqa: E731
    row3 = lambda j: (j, 0, 0)                      # noqa: E731
    full = lambda j: (0,)                           # noqa: E731
    ends, starts = pl.pallas_call(
        kernel,
        grid=(T,),
        in_specs=[
            pl.BlockSpec((1, K), row),
            pl.BlockSpec((1, K, 3), row3),
            pl.BlockSpec((1, K, 3), row3),
            pl.BlockSpec((1, K), row),
        ],
        out_specs=[pl.BlockSpec((n_slots,), full),
                   pl.BlockSpec((n_slots,), full)],
        out_shape=[jax.ShapeDtypeStruct((n_slots,), dtype),
                   jax.ShapeDtypeStruct((n_slots,), dtype)],
        interpret=interpret,
    )(jnp.asarray(out), jnp.asarray(dep),
      jnp.asarray(delay, dtype=dtype), jnp.asarray(dur, dtype=dtype))
    # pallas wrote per-slot starts directly; return them in the same
    # (ends, per-step starts) convention scan_steps normalizes — remap
    # by gathering the slot starts at each step's out row.
    return ends, jnp.asarray(starts)[jnp.asarray(out)]
