"""Optimizer, checkpoint, data pipeline, fault tolerance, compression."""
import os
import subprocess
import sys
import tempfile

import pytest

try:
    import hypothesis as hp
    import hypothesis.strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # optional dependency; spot-checks still run
    HAVE_HYPOTHESIS = False

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import DataConfig, DataLoader, synth_batch
from repro.train import checkpoint as ckpt
from repro.train import optimizer as opt
from repro.train.compression import (ErrorFeedback, compressed_psum,
                                     dequantize_int8, quantize_int8)
from repro.train.fault_tolerance import (ElasticPlan, HeartbeatMonitor,
                                         replan_mesh, run_with_recovery)


# ---------------------------- optimizer ----------------------------

def test_adamw_minimizes_quadratic():
    cfg = opt.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1,
                          total_steps=200)
    params = {"w": jnp.array([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, state, _ = opt.update(cfg, params, grads, state)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_grad_clipping():
    cfg = opt.AdamWConfig(lr=0.0, grad_clip=1.0)
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)
    _, _, metrics = opt.update(cfg, params, {"w": jnp.full(3, 100.0)},
                               state)
    assert float(metrics["grad_norm"]) > 100


def test_lr_schedule_shape():
    cfg = opt.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                          min_lr_frac=0.1)
    lrs = [float(opt.lr_schedule(cfg, jnp.array(s))) for s in range(100)]
    assert lrs[0] < lrs[9]                      # warmup rising
    assert max(lrs) <= 1.0 + 1e-6
    assert lrs[-1] >= 0.1 * 0.99                # floor respected
    assert lrs[50] > lrs[99]                    # decaying


# ---------------------------- checkpoint ----------------------------

def _tree(key):
    return {"a": jax.random.normal(key, (4, 8)),
            "nested": {"b": jnp.arange(6, dtype=jnp.int32)}}


def test_checkpoint_roundtrip():
    tree = _tree(jax.random.PRNGKey(0))
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 7, tree)
        restored, step = ckpt.restore(d, tree)
        assert step == 7
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_retention_and_latest():
    tree = _tree(jax.random.PRNGKey(1))
    with tempfile.TemporaryDirectory() as d:
        for s in (1, 2, 3, 4, 5):
            ckpt.save(d, s, tree, keep=2)
        assert ckpt.all_steps(d) == [4, 5]
        assert ckpt.latest_step(d) == 5


def test_checkpoint_keep_zero_retains_nothing():
    """Regression: ``steps[:-0]`` is the empty slice, so keep=0 used to
    silently retain EVERY checkpoint — the opposite of its meaning."""
    tree = _tree(jax.random.PRNGKey(3))
    with tempfile.TemporaryDirectory() as d:
        for s in (1, 2, 3):
            ckpt.save(d, s, tree, keep=0)
        assert ckpt.all_steps(d) == []
        with pytest.raises(ValueError):
            ckpt.save(d, 4, tree, keep=-1)


def test_checkpoint_shape_mismatch_fails_loudly():
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 1, {"a": jnp.zeros((2, 2))})
        with pytest.raises(ValueError):
            ckpt.restore(d, {"a": jnp.zeros((3, 3))})


def test_checkpoint_dtype_mismatch_fails_loudly():
    """Regression: restore used to silently astype, hiding config drift
    (e.g. fp32 optimizer moments quietly rounded into a bf16 slot)."""
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 1, {"a": jnp.zeros((2, 2), dtype=jnp.float32)})
        with pytest.raises(ValueError, match="dtype mismatch"):
            ckpt.restore(d, {"a": jnp.zeros((2, 2), dtype=jnp.int32)})


def test_checkpoint_atomicity_tmp_never_latest():
    """A stale .tmp dir (simulated crash) must be invisible to restore."""
    tree = _tree(jax.random.PRNGKey(2))
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 1, tree)
        os.makedirs(os.path.join(d, "step_00000002.tmp"))
        assert ckpt.latest_step(d) == 1


def test_checkpoint_manifest_helpers_are_numpy_only():
    """The manifest helpers feed engine-side restore sizing
    (``repro.core.perturb``); importing the module must not drag jax
    in — checked in a fresh interpreter so this process's imports
    can't mask it."""
    m = ckpt.synthetic_manifest(4, {"pos0/params": 1000.0,
                                    "pos1/params": 24.0})
    assert m["step"] == 4
    assert [e["shape"] for e in m["leaves"]] == [[250], [6]]
    assert ckpt.manifest_nbytes(m) == 250 * 4 + 6 * 4
    src = os.path.join(os.path.dirname(__file__), os.pardir, "src")
    code = ("import sys\n"
            "import repro.train.checkpoint as c\n"
            "m = c.synthetic_manifest(0, {'pos0/params': 64.0})\n"
            "assert c.manifest_nbytes(m) == 64.0\n"
            "assert 'jax' not in sys.modules, 'checkpoint imported jax'\n")
    out = subprocess.run([sys.executable, "-c", code], text=True,
                         capture_output=True,
                         env={**os.environ, "PYTHONPATH": src})
    assert out.returncode == 0, out.stderr


# ---------------------------- data ----------------------------

def test_data_deterministic_and_resumable():
    cfg = DataConfig(seed=5, vocab=100, seq_len=16, global_batch=4)
    b1 = synth_batch(cfg, 3)
    b2 = synth_batch(cfg, 3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # loader starting at step 3 produces the same batch
    loader = DataLoader(cfg, start_step=3)
    step, batch = next(loader)
    loader.close()
    assert step == 3
    np.testing.assert_array_equal(batch["tokens"], b1["tokens"])


def test_data_shards_disjoint():
    c0 = DataConfig(seed=1, vocab=50, seq_len=8, global_batch=8,
                    shard_index=0, shard_count=2)
    c1 = DataConfig(seed=1, vocab=50, seq_len=8, global_batch=8,
                    shard_index=1, shard_count=2)
    b0, b1 = synth_batch(c0, 0), synth_batch(c1, 0)
    assert b0["tokens"].shape == (4, 8)
    assert not np.array_equal(b0["tokens"], b1["tokens"])


def test_labels_shifted():
    cfg = DataConfig(seed=2, vocab=100, seq_len=16, global_batch=2)
    b = synth_batch(cfg, 0)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])
    assert (b["labels"][:, -1] == -1).all()


# ---------------------------- fault tolerance ----------------------------

def test_straggler_detection():
    mon = HeartbeatMonitor(4, straggler_factor=1.5)
    for step in range(8):
        for w in range(4):
            mon.heartbeat(w, 1.0 if w != 2 else 2.5, now=float(step))
    assert mon.stragglers() == [2]


def test_dead_worker_detection_is_pure_query():
    """Regression: ``dead()`` used to flip ``alive`` as a side effect,
    so a second poller (or a repeated poll) saw an empty dead set and
    never triggered recovery. Detection and transition are now split."""
    mon = HeartbeatMonitor(3, dead_after_s=10)
    for w in range(3):
        mon.heartbeat(w, 1.0, now=0.0)
    mon.heartbeat(0, 1.0, now=20.0)
    mon.heartbeat(1, 1.0, now=20.0)
    assert mon.dead(now=25.0) == [2]
    assert mon.dead(now=25.0) == [2]            # still visible
    assert mon.alive_count() == 3               # no mutation yet
    assert mon.mark_dead(now=25.0) == [2]
    assert mon.alive_count() == 2
    assert mon.dead(now=25.0) == []             # transitioned
    assert mon.mark_dead([2]) == []             # already dead: no-op


def test_dead_worker_rejoins_on_heartbeat():
    """Elastic rescheduling brings a node back: its heartbeat re-joins
    it and drops the stale step-time history (so the revived worker is
    not instantly flagged a straggler on pre-death data)."""
    mon = HeartbeatMonitor(2, dead_after_s=10)
    mon.heartbeat(0, 1.0, now=0.0)
    mon.heartbeat(1, 9.0, now=0.0)
    mon.mark_dead(now=20.0)
    assert mon.alive_count() == 0
    mon.heartbeat(1, 1.0, now=21.0)
    assert mon.alive_count() == 1
    assert mon.workers[1].step_times == [1.0]   # stale history dropped


def test_replan_mesh_boundaries():
    with pytest.raises(ValueError):
        replan_mesh(0, 4)
    with pytest.raises(ValueError):
        replan_mesh(-3, 1)
    assert replan_mesh(1, 1) == ElasticPlan(data=1, model=1)
    # survivors < model group: mp halves until it fits
    assert replan_mesh(3, 8) == ElasticPlan(data=1, model=2)
    assert replan_mesh(1, 8) == ElasticPlan(data=1, model=1)
    # model group kept intact when it fits; data is power-of-two
    assert replan_mesh(7, 4) == ElasticPlan(data=1, model=4)
    assert replan_mesh(8, 4) == ElasticPlan(data=2, model=4)
    assert replan_mesh(513, 4) == ElasticPlan(data=128, model=4)


if HAVE_HYPOTHESIS:
    @hp.given(survivors=st.integers(1, 512),
              mp=st.sampled_from([1, 2, 4, 8, 16]))
    @hp.settings(max_examples=50, deadline=None)
    def test_replan_mesh_feasible(survivors, mp):
        plan = replan_mesh(survivors, mp)
        assert plan.devices <= survivors
        assert plan.devices >= max(1, survivors // 4)   # wastes <75%
        assert plan.model <= mp


@pytest.mark.parametrize("survivors,mp", [
    (1, 1), (3, 2), (5, 4), (9, 8), (31, 16), (512, 16),
])
def test_replan_mesh_spot_checks(survivors, mp):
    plan = replan_mesh(survivors, mp)
    assert plan.devices <= survivors
    assert plan.devices >= max(1, survivors // 4)
    assert plan.model <= mp


def test_run_with_recovery_loses_bounded_steps():
    saved = {"step": 0}
    done = []

    def step_fn(s):
        done.append(s)

    def save_fn(s):
        saved["step"] = s

    def restore_fn():
        return saved["step"]

    steps, recoveries = run_with_recovery(
        50, step_fn, save_fn, restore_fn, save_every=10, failure_at=25)
    assert steps == 50
    assert recoveries == 1
    # lost work bounded by save_every: checkpoint at 20 ⇒ steps 20-24
    # re-execute once, 19 and earlier never re-run
    assert done.count(19) == 1 and done.count(20) == 2


def test_run_with_recovery_budget_stops_persistent_failure():
    """Regression: a step that deterministically raises used to loop
    forever (restore rewinds to the same step, which fails again).
    The recovery budget re-raises with the original failure chained."""
    attempts = []

    def step_fn(s):
        if s == 3:
            attempts.append(s)
            raise RuntimeError("bad node")

    with pytest.raises(RuntimeError, match="recovery budget") as ei:
        run_with_recovery(10, step_fn, lambda s: None, lambda: 0,
                          save_every=100, max_recoveries=4)
    assert len(attempts) == 5                   # initial try + 4 retries
    assert isinstance(ei.value.__cause__, RuntimeError)
    assert "bad node" in str(ei.value.__cause__)


# ---------------------------- compression ----------------------------

def _quantize_error_bound(seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (256,)) * 3.0
    q, scale = quantize_int8(x)
    err = jnp.abs(dequantize_int8(q, scale) - x)
    assert float(err.max()) <= float(scale) / 2 + 1e-6


if HAVE_HYPOTHESIS:
    @hp.given(seed=st.integers(0, 10))
    @hp.settings(max_examples=10, deadline=None)
    def test_quantize_error_bound(seed):
        _quantize_error_bound(seed)


@pytest.mark.parametrize("seed", [0, 3, 7])
def test_quantize_error_bound_spot_checks(seed):
    _quantize_error_bound(seed)


def test_error_feedback_unbiased_over_time():
    """Accumulated sent updates converge to accumulated true gradient."""
    key = jax.random.PRNGKey(0)
    g_true = jax.random.normal(key, (64,))
    resid = ErrorFeedback.init({"g": g_true})
    total_sent = jnp.zeros(64)
    for i in range(50):
        sent, resid = ErrorFeedback.apply({"g": g_true}, resid)
        total_sent = total_sent + sent["g"]
    np.testing.assert_allclose(np.asarray(total_sent / 50),
                               np.asarray(g_true), atol=0.02)


def test_compressed_psum_single_axis():
    mesh = jax.make_mesh((1,), ("data",))
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    g = {"w": jnp.linspace(-1, 1, 32)}
    f = shard_map(lambda t: compressed_psum(t, "data"), mesh=mesh,
                  in_specs=(P(),), out_specs=P())
    out = f(g)
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(g["w"]),
                               atol=0.02)
