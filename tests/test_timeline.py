"""Timeline utilities: empty-device edge cases and the per-activity
error metrics that back ``repro.validate``."""
import pytest

from repro.configs.base import get_config, smoke_config
from repro.core import (A40_CLUSTER, AnalyticalProvider, DistSim, Strategy,
                        Timeline)
from repro.core.timeline import (Activity, activity_duration_error,
                                 batch_time_error, error_summary,
                                 utilization_delta)

PROVIDER = AnalyticalProvider(A40_CLUSTER)


def test_empty_timeline_reports_zero_utilization():
    """Edge case: no activities at all — utilization must be 0.0 for
    every device, no division error, no bubbles."""
    tl = Timeline([], n_devices=4)
    assert tl.batch_time == 0.0
    assert tl.utilization() == {d: 0.0 for d in range(4)}
    assert tl.bubble_fraction() == 0.0
    assert tl.by_device() == {d: [] for d in range(4)}


def test_zero_duration_activities_zero_utilization():
    """All-zero-duration events (pp stages with no layers emit 0-width
    OPT events) → batch_time 0, utilization 0.0 everywhere."""
    acts = [Activity(device=d, name=f"OPT:d{d}", kind="OPT",
                     start=0.0, end=0.0) for d in range(2)]
    tl = Timeline(acts, n_devices=2)
    assert tl.batch_time == 0.0
    assert tl.utilization() == {0: 0.0, 1: 0.0}


def test_device_with_no_activities_is_zero_not_missing():
    tl = Timeline([Activity(device=0, name="F:s0:m0", kind="F",
                            start=0.0, end=1.0)], n_devices=3)
    util = tl.utilization()
    assert util[0] == 1.0
    assert util[1] == 0.0 and util[2] == 0.0


def test_degenerate_pp_with_empty_stages_end_to_end():
    """pp larger than the layer count: trailing stages own no layers,
    yet prediction and replay still produce finite metrics."""
    cfg = smoke_config(get_config("gpt2_345m"))    # 2 layers
    sim = DistSim(cfg, Strategy(pp=4, microbatches=4), 4, 64, PROVIDER)
    pred = sim.simulate().result()
    act = sim.simulate(seeds=(0,)).result()
    assert pred.batch_time > 0
    assert all(0.0 <= u <= 1.0 for u in pred.utilization.values())
    s = error_summary(pred.timeline, act.timeline)
    assert all(v == v and v >= 0.0 for v in s.values())   # finite, no NaN


def test_error_metrics_zero_on_identical():
    sim = DistSim(get_config("bert_large"), Strategy(pp=2, dp=2,
                                                     microbatches=4),
                  16, 128, PROVIDER)
    tl = sim.simulate().timeline()
    assert batch_time_error(tl, tl) == 0.0
    assert all(v == 0.0 for v in activity_duration_error(tl, tl).values())
    assert all(v == 0.0 for v in utilization_delta(tl, tl).values())
    assert all(v == 0.0 for v in error_summary(tl, tl).values())


def test_error_summary_tracks_jitter():
    sim = DistSim(get_config("bert_large"), Strategy(pp=2, dp=2,
                                                     microbatches=4),
                  16, 128, PROVIDER)
    pred = sim.simulate().result()
    act = sim.simulate(seeds=(1,)).result()
    s = error_summary(pred.timeline, act.timeline)
    assert s["batch_time_error"] == pytest.approx(
        batch_time_error(pred.timeline, act.timeline))
    assert 0.0 < s["activity_error_max"] < 0.05
    assert s["activity_error_mean"] <= s["activity_error_max"]
    assert s["stage_error_mean"] <= s["stage_error_max"]


def test_error_summary_empty_vs_empty():
    e = Timeline([], n_devices=2)
    s = error_summary(e, e)
    assert all(v == 0.0 for v in s.values())
