import os

# smoke tests and benches must see the single real CPU device — the
# 512-device XLA flag belongs ONLY to repro.launch.dryrun (see spec).
assert "xla_force_host_platform_device_count" not in \
    os.environ.get("XLA_FLAGS", "")

# construction-time static graph verification (repro.analyze) is ON
# for the whole suite: every engine/mega-batch any test builds gets
# the invariant check for free. Hot paths (benchmarks, search) leave
# the variable unset and pay nothing.
os.environ.setdefault("REPRO_VERIFY", "1")

import jax

jax.config.update("jax_platform_name", "cpu")
