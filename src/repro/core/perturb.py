"""Perturbation axis: stragglers and injected failures as first-class
simulation inputs (ROADMAP "failure/straggler scenarios").

The paper's §6 use-case — evaluate a strategy *before* renting the
cluster — only covers the happy path. This module extends the same
event machinery to degraded fleets:

* :class:`Straggler` — a per-device slowdown multiplier over a step
  window. Inside a step, TPU/GPU SPMD is bulk-synchronous, so a slow
  device stretches every event it executes; the engine applies the
  multiplier to the replay-side ``speed`` plane (the exact mechanism
  the stochastic ``straggler_sigma`` noise already uses), which keeps
  the zero-perturbation path bit-identical.

* :class:`Fault` — rank dies at the start of a step. Recovery is
  modeled as timeline events, wiring the dormant seed subsystems into
  the engine: a restore-read ``hbm`` event sized from a
  :mod:`repro.train.checkpoint` manifest, a mesh re-plan via
  :func:`repro.train.fault_tolerance.replan_mesh`, and resumed steps on
  the surviving :class:`~repro.train.fault_tolerance.ElasticPlan` grid
  (recomputing the steps lost since the last checkpoint).

* :func:`simulate_degraded` — splices segments and recovery sub-graphs
  into one :class:`DegradedRun`; the public entry point is
  ``DistSim.simulate(perturb=...)``.

Design invariants (the repo's standing bit-identity bar):

* ``perturb=None`` — and an empty :class:`Perturbation` — leave every
  replay/predict path byte-identical to the unperturbed engine: no
  extra RNG draws, no changed operand pairings, no key changes.
* Builds, engines, store addresses and serve-query serialization do
  NOT depend on the perturbation: a perturbation multiplies profiled
  means at run evaluation time, so ``ProfileStore``/``BuildCache``
  keys carry no perturb field and every existing address stays
  byte-identical (the PR 8 scenario-key pattern: optional axis
  serialized only when present).
* Straggler ``rank`` is the flat device index of the ``(dp, pp, mp)``
  grid — ``rank = (r * pp + d) * mp + j`` — matching the engine's
  activity device numbering. SPMD lockstep means a straggling rank
  stalls its whole mp group, so the grid resolves to a ``(dp, pp)``
  multiplier plane.
* After an elastic re-plan the flagged stragglers are excluded from
  the surviving grid (fault-tolerance mitigation (b): straggling ranks
  are dropped at the next re-plan), so post-failure segments run clean.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.engine import EventFlowEngine
from repro.core.events import Event, Strategy
from repro.train.checkpoint import manifest_nbytes, synthetic_manifest
from repro.train.fault_tolerance import ElasticPlan, replan_mesh

#: open-ended straggler window sentinel (active until the run ends)
OPEN = -1


@dataclasses.dataclass(frozen=True)
class Straggler:
    """Rank runs ``factor``x slower over ``window = [start, stop)``
    run steps (``stop = OPEN`` keeps it active until the end)."""
    rank: int
    factor: float
    window: Tuple[int, int] = (0, OPEN)

    def __post_init__(self):
        object.__setattr__(self, "window", tuple(self.window))
        if self.rank < 0:
            raise ValueError(f"straggler rank must be >= 0, got {self.rank}")
        if not self.factor > 0:
            raise ValueError(
                f"straggler factor must be > 0, got {self.factor}")
        w0, w1 = self.window
        if w0 < 0 or (w1 != OPEN and w1 <= w0):
            raise ValueError(f"bad straggler window {self.window}: want "
                             f"(start >= 0, stop > start or OPEN)")

    def covers(self, step: int) -> bool:
        w0, w1 = self.window
        return w0 <= step and (w1 == OPEN or step < w1)


@dataclasses.dataclass(frozen=True)
class Fault:
    """Rank dies at the start of run step ``at_step``; ``detect_s`` is
    the heartbeat-timeout detection latency charged before recovery."""
    rank: int
    at_step: int
    detect_s: float = 0.0

    def __post_init__(self):
        if self.rank < 0:
            raise ValueError(f"fault rank must be >= 0, got {self.rank}")
        if self.at_step < 0:
            raise ValueError(
                f"fault at_step must be >= 0, got {self.at_step}")
        if self.detect_s < 0:
            raise ValueError(
                f"fault detect_s must be >= 0, got {self.detect_s}")


@dataclasses.dataclass(frozen=True)
class Perturbation:
    """A degraded-fleet scenario: stragglers + faults over a run of
    ``steps`` training/serving iterations, checkpointing every
    ``save_every`` steps (absolute step numbers), with ``replan_s``
    seconds of mesh re-plan overhead charged per recovery."""
    stragglers: Tuple[Straggler, ...] = ()
    faults: Tuple[Fault, ...] = ()
    steps: int = 16
    save_every: int = 4
    replan_s: float = 0.0

    def __post_init__(self):
        object.__setattr__(self, "stragglers", tuple(self.stragglers))
        faults = tuple(sorted(self.faults, key=lambda f: f.at_step))
        object.__setattr__(self, "faults", faults)
        if self.steps < 1:
            raise ValueError(f"steps must be >= 1, got {self.steps}")
        if self.save_every < 1:
            raise ValueError(
                f"save_every must be >= 1, got {self.save_every}")
        if self.replan_s < 0:
            raise ValueError(
                f"replan_s must be >= 0, got {self.replan_s}")
        ranks = [f.rank for f in faults]
        if len(set(ranks)) != len(ranks):
            raise ValueError(f"duplicate fault ranks: {ranks}")
        for f in faults:
            if f.at_step >= self.steps:
                raise ValueError(
                    f"fault at_step {f.at_step} outside the run "
                    f"(steps={self.steps})")

    # ---- engine-facing views ----

    def speed_grid(self, strat: Strategy) -> Optional[np.ndarray]:
        """(dp, pp) duration multiplier plane, or None when no
        straggler is present (the engine then takes the exact
        unperturbed path). All stragglers in the spec are applied —
        window selection happens at the run level via :meth:`active`;
        callers that splice segments pass per-segment sub-specs."""
        if not self.stragglers:
            return None
        dp, pp, mp = strat.dp, strat.pp, strat.mp
        world = dp * pp * mp
        grid = np.ones((dp, pp))
        for s in self.stragglers:
            if s.rank >= world:
                raise ValueError(
                    f"straggler rank {s.rank} out of range for the "
                    f"{world}-device strategy {strat.label()}")
            r, d = divmod(s.rank // mp, pp)
            grid[r, d] *= s.factor
        return grid

    def pipe_scale(self, strat: Strategy) -> Optional[np.ndarray]:
        """(pp,) per-pipeline-device multiplier for single-replica
        array programs (:class:`repro.core.megabatch.MegaBatch`);
        raises when the effect varies across DP replicas (the
        single-replica program cannot represent that — use
        ``EventFlowEngine.run``/``run_batched`` instead)."""
        grid = self.speed_grid(strat)
        if grid is None:
            return None
        if strat.dp > 1 and not bool(np.all(grid == grid[0])):
            raise ValueError(
                "mega-batch predict needs straggler effects uniform "
                "across DP replicas; use EventFlowEngine.run/"
                "run_batched for per-replica perturbations")
        return grid[0]

    def active(self, step: int) -> Tuple[Straggler, ...]:
        """Stragglers whose window covers ``step``."""
        return tuple(s for s in self.stragglers if s.covers(step))

    # ---- serde (report/query embedding) ----

    def to_dict(self) -> Dict:
        return {
            "stragglers": [_straggler_dict(s) for s in self.stragglers],
            "faults": [dataclasses.asdict(f) for f in self.faults],
            "steps": self.steps,
            "save_every": self.save_every,
            "replan_s": self.replan_s,
        }

    def label(self) -> str:
        parts = []
        for s in self.stragglers:
            w = ("" if s.window == (0, OPEN)
                 else f"@{s.window[0]}:{s.window[1]}")
            parts.append(f"slow{s.rank}x{s.factor:g}{w}")
        for f in self.faults:
            parts.append(f"fault{f.rank}@{f.at_step}")
        return "+".join(parts) if parts else "clean"


def _straggler_dict(s: Straggler) -> Dict:
    # JSON-native (window as a list), so to_dict() round-trips through
    # json.dumps unchanged
    return {"rank": s.rank, "factor": s.factor, "window": list(s.window)}


def perturbation_from_dict(d: Optional[Dict]) -> Optional[Perturbation]:
    """Inverse of :meth:`Perturbation.to_dict`; ``None`` (the omitted
    default in serialized queries/reports) stays ``None``."""
    if d is None:
        return None
    return Perturbation(
        stragglers=tuple(Straggler(rank=s["rank"], factor=s["factor"],
                                   window=tuple(s.get("window", (0, OPEN))))
                         for s in d.get("stragglers", ())),
        faults=tuple(Fault(**f) for f in d.get("faults", ())),
        steps=d.get("steps", 16),
        save_every=d.get("save_every", 4),
        replan_s=d.get("replan_s", 0.0),
    )


# --------------------------------------------------------------------------
# degraded-run composition (segments + recovery sub-graphs)
# --------------------------------------------------------------------------


@dataclasses.dataclass
class Segment:
    """A run span of identical per-step conditions: ``[start, stop)``
    steps on one strategy grid under one active-straggler set."""
    start: int
    stop: int
    strategy: str                       # Strategy.label() of the grid
    stragglers: Tuple[Straggler, ...]
    step_times: np.ndarray              # (S,) per replay lane

    @property
    def total(self) -> np.ndarray:
        return (self.stop - self.start) * self.step_times

    def to_dict(self) -> Dict:
        return {"start": self.start, "stop": self.stop,
                "strategy": self.strategy,
                "stragglers": [_straggler_dict(s)
                               for s in self.stragglers],
                "step_times": [float(t) for t in self.step_times]}


@dataclasses.dataclass
class RecoveryEvent:
    """One component of a recovery sub-graph (spliced at the failure
    step): detect / restore / replan / recompute."""
    kind: str
    duration: np.ndarray                # (S,) per replay lane

    def to_dict(self) -> Dict:
        return {"kind": self.kind,
                "duration": [float(t) for t in self.duration]}


@dataclasses.dataclass
class FaultRecovery:
    """The recovery timeline spliced for one fault."""
    fault: Fault
    ckpt_step: int                      # checkpoint restored from
    lost_steps: int                     # recomputed on the new grid
    survivors: int
    plan: ElasticPlan
    restore_bytes: float                # manifest total (all devices)
    events: List[RecoveryEvent]

    @property
    def recovery_times(self) -> np.ndarray:
        """(S,) failure-to-caught-up time: detect + restore + replan +
        recompute of the steps lost since the checkpoint."""
        out = np.zeros_like(self.events[0].duration)
        for e in self.events:
            out = out + e.duration
        return out

    def to_dict(self) -> Dict:
        return {"rank": self.fault.rank, "at_step": self.fault.at_step,
                "ckpt_step": self.ckpt_step,
                "lost_steps": self.lost_steps,
                "survivors": self.survivors,
                "plan": {"data": self.plan.data, "model": self.plan.model},
                "restore_bytes": self.restore_bytes,
                "recovery_times": [float(t) for t in self.recovery_times],
                "events": [e.to_dict() for e in self.events]}


@dataclasses.dataclass
class DegradedRun:
    """Result of ``DistSim.simulate(perturb=...)``: the spliced
    timeline of a perturbed multi-step run. Arrays are (S,) — one entry
    per replay lane (S=1 zero-noise predict when ``seeds`` is None)."""
    perturb: Perturbation
    seeds: List[Optional[int]]
    steps: int                          # run steps actually delivered
    baseline_step_time: np.ndarray      # (S,) unperturbed original grid
    segments: List[Segment]
    recoveries: List[FaultRecovery]
    entries: List                       # ordered ("segment"|"recovery", x)
    final_strategy: Strategy
    post_failure_step_time: np.ndarray  # (S,) clean final grid
    post_failure_throughput: np.ndarray  # (S,) tokens/sec on final grid
    effective_global_batch: int

    @property
    def total_times(self) -> np.ndarray:
        """(S,) wall-clock of the whole perturbed run."""
        out = np.zeros_like(self.baseline_step_time)
        for kind, x in self.entries:
            out = out + (x.total if kind == "segment"
                         else x.recovery_times)
        return out

    @property
    def steps_lost(self) -> int:
        return sum(r.lost_steps for r in self.recoveries)

    def timeline(self, lane: int = 0) -> List[Tuple[str, float, float, str]]:
        """Flat ``(kind, t0, t1, label)`` spans for lane ``lane`` —
        segments and recovery components in splice order."""
        out: List[Tuple[str, float, float, str]] = []
        t = 0.0
        for kind, x in self.entries:
            if kind == "segment":
                dt = float(x.total[lane])
                lab = (f"steps {x.start}..{x.stop} on {x.strategy}"
                       + (f" ({len(x.stragglers)} stragglers)"
                          if x.stragglers else ""))
                out.append(("steps", t, t + dt, lab))
                t += dt
            else:
                for e in x.events:
                    dt = float(e.duration[lane])
                    out.append((e.kind, t, t + dt,
                                f"rank {x.fault.rank} fault @ step "
                                f"{x.fault.at_step}"))
                    t += dt
        return out

    def to_dict(self) -> Dict:
        return {
            "perturb": self.perturb.to_dict(),
            "seeds": list(self.seeds),
            "steps": self.steps,
            "steps_lost": self.steps_lost,
            "baseline_step_time": [float(t)
                                   for t in self.baseline_step_time],
            "total_times": [float(t) for t in self.total_times],
            "post_failure_step_time": [
                float(t) for t in self.post_failure_step_time],
            "post_failure_throughput": [
                float(t) for t in self.post_failure_throughput],
            "effective_global_batch": self.effective_global_batch,
            "final_strategy": self.final_strategy.to_dict(),
            "segments": [s.to_dict() for s in self.segments],
            "recoveries": [r.to_dict() for r in self.recoveries],
        }


def restore_manifest(stages, strat: Strategy, step: int) -> Dict:
    """Synthetic checkpoint manifest for one strategy's shards: per
    pipeline position, the mp-sharded params plus the two AdamW moments
    (dp-sharded under ZeRO-1) — the bytes a real ``checkpoint.save``
    manifest of this model would describe, without writing arrays."""
    named: Dict[str, float] = {}
    for p, st in enumerate(stages):
        shard = st.param_bytes / max(1, strat.mp)
        moment = shard / strat.dp if strat.zero1 else shard
        named[f"pos{p}/params"] = shard
        named[f"pos{p}/adam_m"] = moment
        named[f"pos{p}/adam_v"] = moment
    return synthetic_manifest(step, named)


def _restore_read(manifest: Dict, strat: Strategy, provider
                  ) -> Tuple[float, float]:
    """(restore_time, total_bytes): every surviving pipeline device
    reads its own positions' shards in parallel — one ``hbm`` event per
    device, the recovery time is the slowest read."""
    pp = strat.pp
    per_dev = [0.0] * pp
    for e in manifest["leaves"]:
        p = int(e["path"].split("/", 1)[0][3:])
        n = 1
        for sdim in e["shape"]:
            n *= int(sdim)
        per_dev[p % pp] += n * np.dtype(e["dtype"]).itemsize
    times = [provider.time(Event(kind="hbm", name=f"ckpt_restore:d{d}",
                                 nbytes=b))
             for d, b in enumerate(per_dev)]
    return max(times), manifest_nbytes(manifest)


def simulate_degraded(sim, perturb: Perturbation,
                      seeds: Union[int, Sequence[int], None] = None,
                      jitter_sigma: float = 0.025,
                      straggler_sigma: float = 0.0,
                      clock_sigma: float = 0.0) -> DegradedRun:
    """Model a perturbed ``perturb.steps``-step run of ``sim``.

    Straggler windows split the run into segments (each a perturbed
    per-step engine evaluation); each fault splices a recovery
    sub-graph — detect, checkpoint restore-read (``hbm`` events sized
    from a :func:`restore_manifest`), mesh re-plan
    (:func:`~repro.train.fault_tolerance.replan_mesh`, keeping the
    ``mp*pp`` model group intact or raising), and recomputation of the
    steps lost since the last checkpoint on the surviving grid.

    The surviving grid keeps the microbatch size constant (the
    compiled kernels / stage events are dp-independent), so a shrunk
    fleet delivers a smaller effective global batch:
    ``gb' = gb / dp * dp'``. Post-replan segments run without
    stragglers (flagged ranks are excluded at the re-plan).
    """
    strat0: Strategy = sim.strategy
    sc = sim.scenario
    if perturb.faults and not sc.is_train:
        raise ValueError(
            f"fault recovery (checkpoint restore) is a training-run "
            f"concept; scenario {sc.label()!r} supports stragglers only")
    world = strat0.devices
    for f in perturb.faults:
        if f.rank >= world:
            raise ValueError(
                f"fault rank {f.rank} out of range for the "
                f"{world}-device strategy {strat0.label()}")
    if seeds is None:
        lane_seeds = None
    elif isinstance(seeds, (int, np.integer)):
        lane_seeds = [int(seeds)]
    else:
        lane_seeds = list(seeds)

    def step_times(engine: EventFlowEngine,
                   p: Optional[Perturbation]) -> np.ndarray:
        if lane_seeds is None:
            return engine.run_batched(None, perturb=p).batch_times
        return engine.run_batched(
            lane_seeds, jitter_sigma=jitter_sigma,
            straggler_sigma=straggler_sigma, clock_sigma=clock_sigma,
            perturb=p).batch_times

    base_engine: EventFlowEngine = sim.engine()
    baseline = step_times(base_engine, None)
    S = len(baseline)

    engines: Dict[Strategy, EventFlowEngine] = {strat0: base_engine}

    def engine_for(strat: Strategy) -> EventFlowEngine:
        eng = engines.get(strat)
        if eng is None:
            # stage events are dp-independent (microbatch held
            # constant), so the surviving engine reuses the positions
            eng = EventFlowEngine(base_engine.stages, strat,
                                  sim.provider, scenario=sc)
            engines[strat] = eng
        return eng

    segments: List[Segment] = []
    recoveries: List[FaultRecovery] = []
    entries: List = []

    def run_span(a: int, b: int, engine: EventFlowEngine,
                 strat: Strategy, allow_strag: bool) -> None:
        if b <= a:
            return
        if not (allow_strag and perturb.stragglers):
            pieces = [(a, b, ())]
        else:
            cuts = {a, b}
            for s in perturb.stragglers:
                w0, w1 = s.window
                for c in (w0, b if w1 == OPEN else w1):
                    if a < c < b:
                        cuts.add(c)
            cs = sorted(cuts)
            pieces = [(lo, hi, perturb.active(lo))
                      for lo, hi in zip(cs, cs[1:])]
        for lo, hi, active in pieces:
            p_seg = Perturbation(stragglers=active) if active else None
            seg = Segment(start=lo, stop=hi, strategy=strat.label(),
                          stragglers=tuple(active),
                          step_times=step_times(engine, p_seg))
            segments.append(seg)
            entries.append(("segment", seg))

    mp_model = strat0.mp * strat0.pp
    cur_strat, cur_engine = strat0, base_engine
    step = 0
    dead = 0
    for f in perturb.faults:
        run_span(step, f.at_step, cur_engine, cur_strat,
                 allow_strag=(dead == 0))
        step = f.at_step
        dead += 1
        survivors = world - dead
        plan = replan_mesh(survivors, mp_model)
        if plan.model != mp_model:
            raise ValueError(
                f"unrecoverable failure at step {f.at_step}: "
                f"{survivors} survivors cannot hold the "
                f"mp*pp={mp_model} model-parallel group "
                f"(replan proposes {plan})")
        ckpt_step = (f.at_step // perturb.save_every) * perturb.save_every
        lost = f.at_step - ckpt_step
        new_strat = (cur_strat if plan.data == cur_strat.dp
                     else dataclasses.replace(cur_strat, dp=plan.data))
        new_engine = engine_for(new_strat)
        manifest = restore_manifest(base_engine.stages, cur_strat,
                                    ckpt_step)
        restore_t, total_bytes = _restore_read(manifest, cur_strat,
                                               sim.provider)
        recompute = lost * step_times(new_engine, None)
        rec = FaultRecovery(
            fault=f, ckpt_step=ckpt_step, lost_steps=lost,
            survivors=survivors, plan=plan, restore_bytes=total_bytes,
            events=[
                RecoveryEvent("detect", np.full(S, f.detect_s)),
                RecoveryEvent("restore", np.full(S, restore_t)),
                RecoveryEvent("replan", np.full(S, perturb.replan_s)),
                RecoveryEvent("recompute", recompute),
            ])
        recoveries.append(rec)
        entries.append(("recovery", rec))
        cur_strat, cur_engine = new_strat, new_engine
    run_span(step, perturb.steps, cur_engine, cur_strat,
             allow_strag=(dead == 0))

    post_step = step_times(cur_engine, None)
    gb_eff = (sim.global_batch if cur_strat.dp == strat0.dp
              else (sim.global_batch // strat0.dp) * cur_strat.dp)
    tput = np.divide(sc.tokens(gb_eff, sim.seq), post_step,
                     out=np.zeros_like(post_step), where=post_step > 0)
    return DegradedRun(
        perturb=perturb,
        seeds=(list(lane_seeds) if lane_seeds is not None else [None]),
        steps=perturb.steps,
        baseline_step_time=baseline,
        segments=segments, recoveries=recoveries, entries=entries,
        final_strategy=cur_strat,
        post_failure_step_time=post_step,
        post_failure_throughput=tput,
        effective_global_batch=gb_eff)
