"""Jit'd public wrappers around the Pallas kernels.

``flash_attention`` matches ``repro.models.layers.attention``'s calling
convention ((B,S,H,hd) GQA layout + position arrays) so the model can
select ``attn_impl="pallas"``. On this CPU container the kernels run in
interpret mode (the TPU lowering path is identical code).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as fa
from repro.kernels import rmsnorm as rn

INTERPRET = True    # CPU container; False on real TPU


@functools.partial(jax.jit,
                   static_argnames=("causal", "window", "block_q",
                                    "block_kv"))
def flash_attention(q, k, v, q_pos=None, k_pos=None, *, causal=True,
                    window=None, block_q=128, block_kv=128):
    """q: (B,Sq,H,hd); k,v: (B,Sk,KH,hd) GQA. Positions must be
    contiguous 0..S-1 (the kernel derives them from block indices)."""
    b, sq, h, hd = q.shape
    kh = k.shape[2]
    n_rep = h // kh
    if n_rep > 1:
        k = jnp.repeat(k, n_rep, axis=2)
        v = jnp.repeat(v, n_rep, axis=2)
    qb = q.transpose(0, 2, 1, 3).reshape(b * h, sq, hd)
    kb = k.transpose(0, 2, 1, 3).reshape(b * h, -1, hd)
    vb = v.transpose(0, 2, 1, 3).reshape(b * h, -1, hd)
    ob = fa.flash_attention_bh(qb, kb, vb, causal=causal, window=window,
                               block_q=block_q, block_kv=block_kv,
                               interpret=INTERPRET)
    return ob.reshape(b, h, sq, hd).transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows"))
def rmsnorm(x, scale, eps=1e-6, block_rows=128):
    return rn.rmsnorm(x, scale, eps=eps, block_rows=block_rows,
                      interpret=INTERPRET)
