"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, causal=True, window=None):
    """q,k,v: (BH, S, hd), contiguous positions; full-softmax reference."""
    bh, sq, hd = q.shape
    sk = k.shape[1]
    scale = hd ** -0.5
    logits = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= (qpos - kpos) < window
    logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", probs,
                      v.astype(jnp.float32)).astype(q.dtype)


def rmsnorm_ref(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)
            * scale.astype(jnp.float32)).astype(x.dtype)
