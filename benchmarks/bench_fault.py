"""Degraded-fleet gate entry point (CI: fault-smoke job).

Gates the perturbation axis (``DistSim.simulate(perturb=...)``):

1. zero-perturbation replay stays BIT-IDENTICAL — ``perturb=None`` and
   an empty :class:`Perturbation` both reproduce the unperturbed
   engine's predict and seeded-replay outputs byte-for-byte;
2. straggler slowdown is monotone in the factor, with factor 1.0
   exactly equal to the clean run;
3. fault recovery splices consistently: the degraded total equals
   pre-fault steps + recovery components + post-replan steps;
4. the structural degraded matrix (:func:`repro.validate.run_degraded`)
   passes, and its predicted recovery times / post-failure throughput
   match the goldens (``tests/goldens/validation_degraded.json``).

    PYTHONPATH=src python benchmarks/bench_fault.py --smoke
    PYTHONPATH=src python benchmarks/bench_fault.py --update-goldens
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import numpy as np

from repro.configs.base import get_config
from repro.core import (DistSim, Fault, Perturbation, Straggler, Strategy)
from repro.validate import format_degraded_report, run_degraded

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), os.pardir, "tests",
                           "goldens", "validation_degraded.json")


def _sim() -> DistSim:
    return DistSim(get_config("gpt2_345m"),
                   Strategy(mp=1, pp=2, dp=2, microbatches=4,
                            schedule="1f1b"), 16, 512)


def identity_gate() -> dict:
    """perturb=None and an empty Perturbation are bit-identical to the
    unperturbed engine, on both the predict and seeded-replay paths."""
    eng = _sim().engine()
    empty = Perturbation(steps=1)
    pred0 = eng.run_batched(None).batch_times
    pred1 = eng.run_batched(None, perturb=None).batch_times
    pred2 = eng.run_batched(None, perturb=empty).batch_times
    seeds = [0, 1, 2]
    rep0 = eng.run_batched(seeds, jitter_sigma=0.025).batch_times
    rep1 = eng.run_batched(seeds, jitter_sigma=0.025,
                           perturb=empty).batch_times
    seq = eng.run(jitter_sigma=0.025, seed=0, perturb=empty).batch_time
    return {
        "predict_identical": bool(np.array_equal(pred0, pred1)
                                  and np.array_equal(pred0, pred2)),
        "replay_identical": bool(np.array_equal(rep0, rep1)),
        "run_identical": seq == float(rep0[0]),
    }


def monotonicity_gate() -> dict:
    """Slowdown factors 1.0 < 1.25 < 1.5 < 2.0 on pipeline device 1 of
    both replicas: batch time exactly equal at 1.0, strictly
    increasing after."""
    eng = _sim().engine()
    base = float(eng.run_batched(None).batch_times[0])
    times = []
    for f in (1.0, 1.25, 1.5, 2.0):
        p = Perturbation(stragglers=(Straggler(1, f), Straggler(3, f)))
        times.append(float(eng.run_batched(None, perturb=p)
                           .batch_times[0]))
    return {
        "baseline": base,
        "times": times,
        "unit_factor_exact": times[0] == base,
        "strictly_monotone": all(a < b for a, b in zip(times, times[1:])),
    }


def splice_gate() -> dict:
    """The canonical fault cell decomposes exactly: 6 pre-fault steps
    + detect + restore + replan + 2 recomputed steps + 6 post-replan
    steps, with the post-replan grid dp=1 (mp*pp kept intact)."""
    sim = _sim()
    run = sim.simulate(perturb=Perturbation(
        faults=(Fault(3, 6, detect_s=0.5),), steps=12, save_every=4))
    rec = run.recoveries[0]
    expected = (6 * run.baseline_step_time + rec.recovery_times
                + 6 * run.post_failure_step_time)
    return {
        "total": float(run.total_times[0]),
        "recovery": float(rec.recovery_times[0]),
        "decomposes": bool(np.allclose(run.total_times, expected,
                                       rtol=1e-12, atol=0.0)),
        "ckpt_ok": rec.ckpt_step == 4 and rec.lost_steps == 2,
        "replan_ok": run.final_strategy.label() == "1M2P1D"
        and run.effective_global_batch == 8,
        "throughput_positive": bool(
            np.all(run.post_failure_throughput > 0)),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate (identity + monotonicity + splice + "
                         "matrix vs goldens; the default)")
    ap.add_argument("--cluster", default="a40-cluster")
    ap.add_argument("--out", default="degraded_report.json",
                    help="report path ('' to skip writing)")
    ap.add_argument("--update-goldens", action="store_true",
                    help=f"rewrite {os.path.normpath(GOLDEN_PATH)}")
    args = ap.parse_args()
    if args.update_goldens and args.cluster != "a40-cluster":
        ap.error("--update-goldens pins the default cluster — "
                 "tests/test_perturb.py hard-codes it")

    failed = False

    ig = identity_gate()
    print(f"identity gate — predict: {ig['predict_identical']}, "
          f"replay: {ig['replay_identical']}, "
          f"run(): {ig['run_identical']}")
    if not all(ig.values()):
        print("fault/ERROR: zero-perturbation path is not bit-identical",
              file=sys.stderr)
        failed = True

    mg = monotonicity_gate()
    lad = ", ".join(f"{t * 1e3:.2f}ms" for t in mg["times"])
    print(f"monotonicity gate — clean {mg['baseline'] * 1e3:.2f}ms; "
          f"factors 1.0/1.25/1.5/2.0 -> {lad}; "
          f"unit-factor exact: {mg['unit_factor_exact']}, "
          f"strictly monotone: {mg['strictly_monotone']}")
    if not (mg["unit_factor_exact"] and mg["strictly_monotone"]):
        print("fault/ERROR: straggler slowdown not monotone in factor",
              file=sys.stderr)
        failed = True

    sg = splice_gate()
    print(f"splice gate — total {sg['total']:.3f}s (recovery "
          f"{sg['recovery']:.3f}s): decomposes {sg['decomposes']}, "
          f"ckpt {sg['ckpt_ok']}, replan {sg['replan_ok']}, "
          f"throughput>0 {sg['throughput_positive']}")
    if not (sg["decomposes"] and sg["ckpt_ok"] and sg["replan_ok"]
            and sg["throughput_positive"]):
        print("fault/ERROR: fault splice inconsistent", file=sys.stderr)
        failed = True

    report = run_degraded(cluster=args.cluster)
    print()
    print(format_degraded_report(report))
    if not report.passed:
        fails = ", ".join(c.cell.label() for c in report.failures)
        print(f"fault/ERROR: structural violations on {fails}",
              file=sys.stderr)
        failed = True

    if args.update_goldens:
        path = os.path.normpath(GOLDEN_PATH)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        with open(path, "w") as f:
            json.dump(report.to_dict(), f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"goldens written to {path}")
    else:
        path = os.path.normpath(GOLDEN_PATH)
        if os.path.exists(path):
            with open(path) as f:
                golden = json.load(f)
            current = json.loads(json.dumps(report.to_dict(),
                                            sort_keys=True))
            if current != golden:
                print("fault/ERROR: degraded matrix drifted from "
                      f"goldens ({path}); if intentional, rerun with "
                      "--update-goldens", file=sys.stderr)
                failed = True
            else:
                print(f"goldens match ({len(golden['cells'])} cells)")
        else:
            print(f"fault/ERROR: goldens missing at {path}",
                  file=sys.stderr)
            failed = True

    if args.out:
        with open(args.out, "w") as f:
            json.dump(report.to_dict(), f, indent=1)
        print(f"report written to {args.out}")

    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
