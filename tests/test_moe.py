"""MoE: gather implementation vs dense-dispatch reference + invariants."""
import pytest

hp = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MoEConfig
from repro.models.moe import (capacity, moe_dense_dispatch, moe_gather,
                              router_probs)


def _setup(key, t, d, e, f, top_k, cf=1.25):
    mcfg = MoEConfig(n_experts=e, top_k=top_k, d_ff_expert=f,
                     capacity_factor=cf)
    ks = jax.random.split(key, 5)
    params = {
        "router": jax.random.normal(ks[0], (d, e), jnp.float32) * 0.1,
        "w_gate": jax.random.normal(ks[1], (e, d, f), jnp.float32) * 0.1,
        "w_up": jax.random.normal(ks[2], (e, d, f), jnp.float32) * 0.1,
        "w_down": jax.random.normal(ks[3], (e, f, d), jnp.float32) * 0.1,
    }
    x = jax.random.normal(ks[4], (2, t // 2, d), jnp.float32)
    return x, params, mcfg


def test_gather_matches_dense_dispatch():
    x, params, mcfg = _setup(jax.random.PRNGKey(0), 64, 16, 8, 32, 2)
    yg, _ = moe_gather(x, params, mcfg)
    yd, _ = moe_dense_dispatch(x, params, mcfg)
    np.testing.assert_allclose(np.asarray(yg), np.asarray(yd), atol=1e-4,
                               rtol=1e-4)


@hp.given(e=st.sampled_from([4, 8]), top_k=st.sampled_from([1, 2]),
          seed=st.integers(0, 4))
@hp.settings(max_examples=10, deadline=None)
def test_gather_dense_equivalence_property(e, top_k, seed):
    x, params, mcfg = _setup(jax.random.PRNGKey(seed), 32, 8, e, 16, top_k)
    yg, _ = moe_gather(x, params, mcfg)
    yd, _ = moe_dense_dispatch(x, params, mcfg)
    np.testing.assert_allclose(np.asarray(yg), np.asarray(yd), atol=1e-4,
                               rtol=1e-4)


def test_router_weights_normalized():
    x, params, mcfg = _setup(jax.random.PRNGKey(1), 32, 8, 4, 16, 2)
    probs, topi, topw = router_probs(x.reshape(-1, 8), params["router"],
                                     mcfg)
    np.testing.assert_allclose(np.asarray(topw.sum(-1)), 1.0, atol=1e-6)
    assert bool(jnp.all(probs >= 0))


def test_capacity_bounds():
    mcfg = MoEConfig(n_experts=8, top_k=2, d_ff_expert=16)
    assert capacity(4, mcfg) == 4              # never exceeds tokens
    c = capacity(1024, mcfg)
    assert c % 8 == 0
    assert c >= 1024 * 2 // 8


def test_dropless_capacity_factor_exact_for_any_expert_count():
    """capacity(t) == t must hold even when n_experts isn't divisible
    by top_k — a bare E/k factor truncates below t via the int() cast
    (e.g. E=17, k=7, t=49 gave capacity 48)."""
    from repro.models.moe import dropless_capacity_factor
    import dataclasses
    for e in (3, 4, 7, 16, 17, 64):
        for k in (1, 2, 3, 5, 7):
            if k > e:
                continue
            mcfg = MoEConfig(n_experts=e, top_k=k, d_ff_expert=8)
            mcfg = dataclasses.replace(
                mcfg, capacity_factor=dropless_capacity_factor(mcfg))
            for t in (1, 2, 7, 32, 49, 333, 4096):
                assert capacity(t, mcfg) == t, (e, k, t)


def test_grad_flows_through_gates():
    from repro.models.moe import moe_ffn
    x, params, mcfg = _setup(jax.random.PRNGKey(2), 32, 8, 4, 16, 2)

    def loss(p):
        y, aux = moe_ffn(x, p, mcfg)
        return (y ** 2).sum() + 0.01 * aux

    g = jax.grad(loss)(params)
    assert float(jnp.abs(g["router"]).sum()) > 0
    assert float(jnp.abs(g["w_gate"]).sum()) > 0


def test_aux_loss_prefers_balance():
    """Uniform routing gives the minimal aux value (=1 for switch loss)."""
    from repro.models.moe import moe_ffn
    x, params, mcfg = _setup(jax.random.PRNGKey(3), 64, 8, 4, 16, 1)
    params["router"] = jnp.zeros_like(params["router"])   # uniform
    _, aux_uniform = moe_ffn(x, params, mcfg)
    params["router"] = params["router"].at[:, 0].set(10.0)  # collapsed
    _, aux_collapsed = moe_ffn(x, params, mcfg)
    assert float(aux_uniform) < float(aux_collapsed)


def test_ep_a2a_matches_gather_single_shard():
    """Explicit expert-parallel all-to-all path (shard_map) reproduces
    the gather implementation exactly on a degenerate 1x1 mesh (the
    multi-shard difference is local-routing capacity semantics only)."""
    import jax
    from repro.models.layers import ModelOptions
    from repro.models.moe import moe_ffn
    x, params, mcfg = _setup(jax.random.PRNGKey(5), 32, 8, 4, 16, 2)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    opts = ModelOptions(moe_impl="ep_a2a", ep_axis="model",
                        dp_axes=("data",))
    with jax.set_mesh(mesh):
        y_ep, aux_ep = jax.jit(
            lambda x, p: moe_ffn(x, p, mcfg, "ep_a2a", opts))(x, params)
    y_g, aux_g = moe_ffn(x, params, mcfg, "gather")
    np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_g),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(float(aux_ep), float(aux_g), rtol=1e-5)


def test_ep_a2a_grad_flows():
    import jax
    from repro.models.layers import ModelOptions
    from repro.models.moe import moe_ffn
    x, params, mcfg = _setup(jax.random.PRNGKey(6), 32, 8, 4, 16, 2)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    opts = ModelOptions(moe_impl="ep_a2a", ep_axis="model",
                        dp_axes=("data",))

    def loss(p):
        y, aux = moe_ffn(x, p, mcfg, "ep_a2a", opts)
        return (y ** 2).sum() + 0.01 * aux

    with jax.set_mesh(mesh):
        g = jax.jit(jax.grad(loss))(params)
    assert float(jnp.abs(g["w_gate"]).sum()) > 0
    assert float(jnp.abs(g["router"]).sum()) > 0
