"""Pallas TPU flash-attention forward kernel.

TPU-native adaptation (DESIGN.md §2): the (bq x bkv) score tile lives in
VMEM and feeds the MXU directly — the tile never round-trips to HBM
(the pure-JAX flash path pays that traffic; see §Perf). Block sizes are
MXU-aligned (multiples of 128 for the contracting/lane dims).

Grid: (batch*heads, num_q_blocks, num_kv_blocks); running (max, denom,
acc) in VMEM scratch, finalized on the last kv block. Causal/sliding-
window masking is derived from program ids (contiguous positions).

Layout: q,k,v are (BH, S, hd) — ops.py adapts the model's
(B, S, H, hd) GQA layout.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_sc, l_sc, acc_sc, *,
                  scale: float, causal: bool, window, block_q: int,
                  block_kv: int, n_kv: int, seq_k: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    q = q_ref[0].astype(jnp.float32)                  # (bq, hd)
    k = k_ref[0].astype(jnp.float32)                  # (bkv, hd)
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    q_pos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_kv), 0)
    k_pos = kj * block_kv + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_kv), 1)
    mask = k_pos < seq_k                              # kv padding
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_sc[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_sc[...] = l_sc[...] * alpha + p.sum(axis=1)
    acc_sc[...] = acc_sc[...] * alpha[:, None] + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_sc[...] = m_new

    @pl.when(kj == n_kv - 1)
    def _finalize():
        o_ref[0] = (acc_sc[...]
                    / jnp.maximum(l_sc[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def flash_attention_bh(q: jax.Array, k: jax.Array, v: jax.Array, *,
                       causal: bool = True, window=None,
                       block_q: int = 128, block_kv: int = 128,
                       interpret: bool = True) -> jax.Array:
    """q,k,v: (BH, S, hd) with equal q/kv lengths per call. Returns
    (BH, Sq, hd). Pads S to block multiples internally."""
    bh, sq, hd = q.shape
    sk = k.shape[1]
    block_q = min(block_q, max(8, sq))
    block_kv = min(block_kv, max(8, sk))
    pq = (-sq) % block_q
    pk = (-sk) % block_kv
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0)))
    nq = q.shape[1] // block_q
    nk = k.shape[1] // block_kv

    kernel = functools.partial(
        _flash_kernel, scale=hd ** -0.5, causal=causal, window=window,
        block_q=block_q, block_kv=block_kv, n_kv=nk, seq_k=sk)

    out = pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_kv, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_kv, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, q.shape[1], hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :sq]
