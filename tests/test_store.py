"""Persistent profile store + serve front-end (ISSUE 7).

Acceptance pins:
* store-served sweeps — serial, parallel (``jobs=2``), and from a
  FRESH process — are bit-identical to cold in-process runs (same
  ``dumps()`` JSON) and perform ZERO provider evaluations on a warm
  store;
* corrupted entries (garbage JSON shards, truncated build pickles) and
  stale ``cache_version`` entries are rejected and counted, never
  served;
* ``DistSim.serve_batch(queries)`` answers match per-query
  ``DistSim.simulate()`` batch times EXACTLY, and a warm server
  resolves the whole smoke matrix without profiling a single event;
* regression fixes ride along: ``MeasuredProvider.clear_cache()``
  drops the derived jit-timing cache, ``run_sweep`` rejects a cluster
  that disagrees with the provider's, ``SimBatch.throughput_iters``
  never leaks uninitialized memory, and the microbatch floor formula
  lives in exactly one place (``Strategy.microbatch_size``).
"""
import os
import subprocess
import sys
import types

import numpy as np
import pytest

import repro.core  # noqa: F401  — establishes the package import order
from repro.configs.base import get_config, smoke_config
from repro.core import A40_CLUSTER, AnalyticalProvider, DistSim, Strategy
from repro.core.costmodel import CLUSTERS
from repro.core.profiler import MeasuredProvider
from repro.core.simulator import SimBatch
from repro.store import (PersistentBuildCache, ProfileStore, ServeQuery,
                         open_store)
from repro.validate import BuildCache, run_sweep, smoke_matrix
from repro.validate.report import dumps

SEEDS = (0, 1)
MATRIX = smoke_matrix()
SMALL = MATRIX[:4]


def _fresh_provider():
    return AnalyticalProvider(A40_CLUSTER)


# --------------------------------------------------------------------------
# event round-trip: exact floats, structural identity
# --------------------------------------------------------------------------

def test_event_times_roundtrip_bit_exact(tmp_path):
    store = ProfileStore(str(tmp_path))
    p1 = _fresh_provider()
    run_sweep(SMALL, provider=p1, seeds=SEEDS)
    assert store.save_events(p1) == p1.cache_size
    p2 = _fresh_provider()
    assert store.load_events(p2) == p1.cache_size
    # same keys, same floats, to the last bit — JSON repr round-trips
    assert p2.cache_snapshot() == p1.cache_snapshot()
    # loads are neither evaluations nor hits
    assert p2.stats.evaluations == 0 and p2.stats.hits == 0


def test_save_events_idempotent(tmp_path):
    store = ProfileStore(str(tmp_path))
    p = _fresh_provider()
    run_sweep(SMALL, provider=p, seeds=SEEDS)
    assert store.save_events(p) > 0
    assert store.save_events(p) == 0       # identical shard skipped
    assert store.entry_counts(p)["event_shards"] == 1


# --------------------------------------------------------------------------
# store-served sweeps: bit-identity + zero warm evaluations
# --------------------------------------------------------------------------

def test_serial_store_sweep_bit_identical_and_warm(tmp_path):
    cold = run_sweep(MATRIX, provider=_fresh_provider(), seeds=SEEDS)
    p1 = _fresh_provider()
    written = run_sweep(MATRIX, provider=p1, seeds=SEEDS,
                        store=str(tmp_path))
    assert dumps(written) == dumps(cold)
    p2 = _fresh_provider()
    warm = run_sweep(MATRIX, provider=p2, seeds=SEEDS,
                     store=str(tmp_path))
    assert dumps(warm) == dumps(cold)
    # stronger than zero evaluations: persisted EngineBuilds carry the
    # precomputed means, so the provider is never even consulted
    assert p2.stats.lookups == 0
    assert p2.cache_size == p1.cache_size  # events still all loaded


def test_parallel_store_sweep_bit_identical_and_warm(tmp_path):
    cold = run_sweep(MATRIX, provider=_fresh_provider(), seeds=SEEDS)
    p1 = _fresh_provider()
    par = run_sweep(MATRIX, provider=p1, seeds=SEEDS, jobs=2,
                    store=str(tmp_path))
    assert dumps(par) == dumps(cold)
    # serial-equivalent accounting survives the disk hand-off
    assert p1.stats.evaluations == p1.cache_size
    p2 = _fresh_provider()
    warm = run_sweep(MATRIX, provider=p2, seeds=SEEDS, jobs=2,
                     store=str(tmp_path))
    assert dumps(warm) == dumps(cold)
    assert p2.stats.evaluations == 0


def test_cacheless_store_sweep_still_persists(tmp_path):
    p1 = _fresh_provider()
    run_sweep(SMALL, provider=p1, seeds=SEEDS, cache=False,
              store=str(tmp_path))
    p2 = _fresh_provider()
    assert open_store(str(tmp_path)).load_events(p2) == p1.cache_size


def test_cross_process_round_trip(tmp_path):
    """The tentpole claim: a worker process writes the store, a FRESH
    python process reads it — zero evaluations, bit-identical report."""
    cold = run_sweep(SMALL, provider=_fresh_provider(), seeds=SEEDS,
                     store=str(tmp_path))
    src = os.path.abspath(os.path.join(
        os.path.dirname(repro.core.__file__), "..", ".."))
    child = (
        "import sys; sys.path.insert(0, {src!r})\n"
        "import repro.core\n"
        "from repro.core import A40_CLUSTER, AnalyticalProvider\n"
        "from repro.validate import run_sweep, smoke_matrix\n"
        "from repro.validate.report import dumps\n"
        "p = AnalyticalProvider(A40_CLUSTER)\n"
        "r = run_sweep(smoke_matrix()[:4], provider=p, seeds=(0, 1),\n"
        "              store={store!r})\n"
        "assert p.stats.evaluations == 0, p.stats.evaluations\n"
        "sys.stdout.write(dumps(r))\n"
    ).format(src=src, store=str(tmp_path))
    out = subprocess.run([sys.executable, "-c", child],
                         capture_output=True, text=True)
    assert out.returncode == 0, out.stderr
    assert out.stdout == dumps(cold)


def test_build_cache_serves_builds_from_disk(tmp_path):
    store = ProfileStore(str(tmp_path))
    p1 = _fresh_provider()
    bc1 = PersistentBuildCache(p1, store)
    run_sweep(SMALL, provider=p1, seeds=SEEDS, cache=bc1)
    bc1.flush()
    assert store.stats.builds_saved > 0
    store2 = ProfileStore(str(tmp_path))
    bc2 = PersistentBuildCache(_fresh_provider(), store2)
    run_sweep(SMALL, provider=bc2.provider, seeds=SEEDS, cache=bc2)
    assert store2.stats.builds_loaded > 0
    assert bc2.stats.build_misses == 0     # every build came from disk


# --------------------------------------------------------------------------
# rejection: corruption, staleness, namespace isolation
# --------------------------------------------------------------------------

def _warm_store(tmp_path):
    store = ProfileStore(str(tmp_path))
    p = _fresh_provider()
    bc = PersistentBuildCache(p, store)
    run_sweep(SMALL, provider=p, seeds=SEEDS, cache=bc)
    bc.flush()
    return store, p


def test_corrupt_event_shard_rejected(tmp_path):
    store, p = _warm_store(tmp_path)
    d = store._events_dir(p)
    with open(os.path.join(d, "deadbeefdeadbeefdeadbeef.json"), "w") as f:
        f.write("{not json")
    p2 = _fresh_provider()
    store2 = ProfileStore(str(tmp_path))
    assert store2.load_events(p2) == p.cache_size  # good shard still serves
    assert store2.stats.corrupt_rejected == 1


def test_truncated_build_pickle_rejected(tmp_path):
    store, p = _warm_store(tmp_path)
    d = store._builds_dir(p)
    victim = os.path.join(d, sorted(os.listdir(d))[0])
    with open(victim, "rb") as f:
        data = f.read()
    with open(victim, "wb") as f:
        f.write(data[:len(data) // 2])     # truncated mid-pickle
    store2 = ProfileStore(str(tmp_path))
    p2 = _fresh_provider()
    bc2 = PersistentBuildCache(p2, store2)
    res = run_sweep(SMALL, provider=p2, seeds=SEEDS, cache=bc2)
    assert res.passed
    assert store2.stats.corrupt_rejected >= 1
    assert bc2.stats.build_misses >= 1     # recomputed, not served


def test_stale_cache_version_rejected(tmp_path):
    store, p = _warm_store(tmp_path)
    bumped = _fresh_provider()
    bumped.clear_cache()                   # version 0 -> 1
    store2 = ProfileStore(str(tmp_path))
    assert store2.load_events(bumped) == 0
    assert store2.stats.stale_rejected == 1
    # and builds: the persisted version-0 entries must not serve either
    bc = PersistentBuildCache(bumped, store2)
    run_sweep(SMALL, provider=bumped, seeds=SEEDS, cache=bc)
    assert store2.stats.builds_loaded == 0
    assert store2.stats.stale_rejected > 1


def test_namespaces_isolated_per_cluster(tmp_path):
    store, p = _warm_store(tmp_path)
    other_cluster = next(c for c in CLUSTERS.values()
                         if c != A40_CLUSTER)
    foreign = AnalyticalProvider(other_cluster)
    assert store.load_events(foreign) == 0
    assert foreign.cache_size == 0


# --------------------------------------------------------------------------
# serve: the query front-end
# --------------------------------------------------------------------------

def _queries():
    return [ServeQuery(c.arch, c.strategy, c.global_batch, c.seq,
                       smoke=c.smoke) for c in MATRIX]


def test_serve_batch_matches_direct_simulate(tmp_path):
    run_sweep(MATRIX, provider=_fresh_provider(), seeds=SEEDS,
              store=str(tmp_path))
    answers = DistSim.serve_batch(_queries(), str(tmp_path))
    for q, a in zip(_queries(), answers):
        cfg = smoke_config(get_config(q.arch)) if q.smoke \
            else get_config(q.arch)
        sim = DistSim(cfg, q.strategy, q.global_batch, q.seq,
                      _fresh_provider())
        pred = sim.simulate()
        assert a.batch_time == float(pred.batch.batch_times[0])
        assert a.bubble_fraction == pytest.approx(
            float(pred.bubble_fraction()[0]), rel=1e-9)
        assert a.utilization_mean == pytest.approx(1.0 - a.bubble_fraction)
        assert a.throughput_tokens == pytest.approx(
            q.global_batch * q.seq / a.batch_time)


def test_warm_serve_performs_zero_evaluations(tmp_path):
    run_sweep(MATRIX, provider=_fresh_provider(), seeds=SEEDS,
              store=str(tmp_path))
    server = DistSim.serve(str(tmp_path))
    answers = server.answer_batch(_queries())
    assert len(answers) == len(MATRIX)
    snap = server.snapshot()
    stats = snap["clusters"][A40_CLUSTER.name]
    assert stats["evaluations"] == 0       # everything from the store
    assert stats["unique_events"] > 0      # events WERE loaded from disk
    assert snap["queries_answered"] == len(MATRIX)
    # repeat traffic reuses engines + the compiled mega-batch program
    again = server.answer_batch(_queries())
    assert [a.batch_time for a in again] == [a.batch_time for a in answers]
    assert snap["clusters"][A40_CLUSTER.name]["evaluations"] == 0


def test_serve_memory_headroom_and_feasibility(tmp_path):
    ans = DistSim.serve(str(tmp_path)).answer(
        ServeQuery("gpt2_345m", Strategy(mp=1, pp=2, dp=2,
                                         microbatches=4)))
    assert ans.mem_bytes > 0
    assert ans.hbm_headroom == pytest.approx(
        A40_CLUSTER.chip.hbm_bytes * 0.92 - ans.mem_bytes)
    assert ans.feasible == (ans.hbm_headroom > 0)
    d = ans.to_dict()
    assert d["query"]["arch"] == "gpt2_345m"
    assert ServeQuery.from_dict(d["query"]) == ans.query


def test_serve_unknown_cluster_raises(tmp_path):
    server = DistSim.serve(str(tmp_path))
    with pytest.raises(ValueError, match="unknown cluster"):
        server.answer(ServeQuery("gpt2_345m", Strategy(),
                                 cluster="no-such-pod"))


# --------------------------------------------------------------------------
# satellite regressions
# --------------------------------------------------------------------------

def test_measured_clear_cache_clears_group_cache():
    """Regression: clear_cache() used to leave the derived jit-timing
    cache populated, so re-profiling silently reused stale timings."""
    p = MeasuredProvider(A40_CLUSTER)
    p._group_cache[((64, 64, 64),)] = 1.23
    version = p.cache_version
    p.clear_cache()
    assert p._group_cache == {}
    assert p.cache_version == version + 1


def test_run_sweep_rejects_mismatched_cluster():
    """Regression: a cluster disagreeing with the provider's used to be
    silently ignored — the sweep ran on different hardware than asked."""
    other = next(c for c in CLUSTERS.values() if c != A40_CLUSTER)
    with pytest.raises(ValueError, match="disagrees"):
        run_sweep(SMALL, cluster=other, provider=_fresh_provider(),
                  seeds=(0,))
    # an AGREEING pair stays fine
    res = run_sweep(SMALL[:1], cluster=A40_CLUSTER,
                    provider=_fresh_provider(), seeds=(0,))
    assert res.cluster == A40_CLUSTER.name


def test_run_sweep_rejects_plain_cache_with_store(tmp_path):
    p = _fresh_provider()
    with pytest.raises(ValueError, match="plain BuildCache"):
        run_sweep(SMALL, provider=p, seeds=(0,), cache=BuildCache(p),
                  store=str(tmp_path))


def test_throughput_iters_no_uninitialized_memory():
    """Regression: np.divide(where=) without out= left masked lanes as
    uninitialized memory instead of 0.0."""
    bt = np.array([0.5, 0.0, 2.0])
    sb = SimBatch(types.SimpleNamespace(batch_times=bt), 16, 128,
                  "replay")
    ti = sb.throughput_iters()
    assert ti[1] == 0.0
    assert ti[0] == 2.0 and ti[2] == 0.5
    assert np.all(np.isfinite(sb.throughput_tokens()))


def test_microbatch_floor_single_source():
    """The floor formula lives ONCE, on Strategy: DistSim and the
    BuildCache key can never drift again."""
    strat = Strategy(mp=1, pp=2, dp=2, microbatches=4)
    assert strat.microbatch_size(16) == 2
    assert strat.microbatch_size(0) == 1   # the max(1, ...) floor
    sim = DistSim(get_config("gpt2_345m"), strat, 16, 128,
                  _fresh_provider())
    assert sim.microbatch() == strat.microbatch_size(16)
    assert BuildCache._microbatch(strat, 16) == strat.microbatch_size(16)


# --------------------------------------------------------------------------
# gc: shard compaction + stale-entry collection
# --------------------------------------------------------------------------

def test_gc_compacts_shards_round_trip(tmp_path):
    """Multiple shards (two flushes) -> gc -> ONE shard; a fresh
    provider loads the exact same event cache, and a re-sweep through
    the compacted store is bit-identical."""
    store, p = _warm_store(tmp_path)
    # second flush with new content: sweep more cells, flush the delta
    bc = PersistentBuildCache(p, store)
    run_sweep(MATRIX[4:8], provider=p, seeds=SEEDS, cache=bc)
    bc.flush()
    assert store.entry_counts(p)["event_shards"] >= 2
    before = _fresh_provider()
    ProfileStore(str(tmp_path)).load_events(before)

    cold = run_sweep(MATRIX[:8], provider=_fresh_provider(), seeds=SEEDS)
    stats = ProfileStore(str(tmp_path)).gc()
    assert stats["namespaces"] == 1
    assert stats["shards_after"] == 1
    assert stats["events_dropped"] == 0    # same version: nothing lost
    assert store.entry_counts(p)["event_shards"] == 1

    after = _fresh_provider()
    ProfileStore(str(tmp_path)).load_events(after)
    assert after.cache_snapshot() == before.cache_snapshot()
    p2 = _fresh_provider()
    warm = run_sweep(MATRIX[:8], provider=p2, seeds=SEEDS,
                     store=str(tmp_path))
    assert dumps(warm) == dumps(cold)
    assert p2.stats.evaluations == 0       # compacted store still warm


def test_gc_idempotent(tmp_path):
    _warm_store(tmp_path)
    ProfileStore(str(tmp_path)).gc()
    stats = ProfileStore(str(tmp_path)).gc()
    assert stats["shards_before"] == stats["shards_after"] == 1
    assert stats["events_dropped"] == 0
    assert stats["builds_dropped"] == 0


def test_gc_drops_stale_version_orphans(tmp_path):
    """Entries written before a clear_cache() bump are orphans a reader
    would reject anyway — gc removes them from disk. Without a provider
    the live version is the highest present (the most recent writer)."""
    store, p = _warm_store(tmp_path)      # version-0 events + builds
    old_counts = store.entry_counts(p)
    bumped = _fresh_provider()
    bumped.clear_cache()                   # version 0 -> 1
    bc = PersistentBuildCache(bumped, ProfileStore(str(tmp_path)))
    run_sweep(SMALL, provider=bumped, seeds=SEEDS, cache=bc)
    bc.flush()                             # version-1 shard + builds

    stats = ProfileStore(str(tmp_path)).gc()
    assert stats["events_dropped"] > 0
    # the v1 sweep overwrote the stale v0 builds IN PLACE (same content
    # address, save_build refreshes a stale incumbent), so gc finds
    # only live builds left
    assert stats["builds_dropped"] == 0
    assert stats["builds_kept"] == old_counts["builds"]
    # the surviving store serves the bumped provider with zero misses
    fresh = _fresh_provider()
    fresh.clear_cache()
    assert ProfileStore(str(tmp_path)).load_events(fresh) \
        == bumped.cache_size
    # ... and a provider-scoped gc honors ITS version, not the max
    stats2 = ProfileStore(str(tmp_path)).gc(provider=fresh)
    assert stats2["events_dropped"] == 0


def test_gc_removes_corrupt_files(tmp_path):
    store, p = _warm_store(tmp_path)
    with open(os.path.join(store._events_dir(p),
                           "deadbeefdeadbeefdeadbeef.json"), "w") as f:
        f.write("{not json")
    with open(os.path.join(store._builds_dir(p),
                           "deadbeefdeadbeefdeadbeef.pkl"), "wb") as f:
        f.write(b"\x80\x04junk")
    stats = ProfileStore(str(tmp_path)).gc()
    assert stats["builds_dropped"] == 1
    assert not os.path.exists(os.path.join(
        store._events_dir(p), "deadbeefdeadbeefdeadbeef.json"))
    assert not os.path.exists(os.path.join(
        store._builds_dir(p), "deadbeefdeadbeefdeadbeef.pkl"))
    p2 = _fresh_provider()
    assert ProfileStore(str(tmp_path)).load_events(p2) == p.cache_size


def test_gc_cli(tmp_path):
    _warm_store(tmp_path)
    src = os.path.abspath(os.path.join(
        os.path.dirname(repro.core.__file__), "..", ".."))
    out = subprocess.run(
        [sys.executable, "-m", "repro.store", "gc", str(tmp_path),
         "--json"],
        capture_output=True, text=True,
        env={**os.environ, "PYTHONPATH": src})
    assert out.returncode == 0, out.stderr
    import json as _json
    stats = _json.loads(out.stdout)
    assert stats["shards_after"] == 1
    assert stats["builds_kept"] > 0
