"""Serving-scenario benchmark + gate (CI: bench-serving job).

Two parts, both over the serving matrix (prefill + decode cells for
the VLM / SSM-hybrid / MoE families):

1. **Accuracy sweep** — predict() vs multi-seed replay() for every
   serving cell, gated at the same paper §5 thresholds as training
   (<4% batch-time, <5% activity), with goldens under
   ``tests/goldens/validation_serving.json``.
2. **Serve-vs-simulate gate** — every cell is also answered through
   ``DistSim.serve_batch`` over a profile store (the mega-batch scored
   service path); predicted batch time and tokens/sec must be
   BIT-IDENTICAL to the per-engine ``DistSim.simulate()`` answer, and
   a second server over the now-warm store must reproduce them with
   zero provider evaluations.

Also prints the throughput table (prefill tokens/sec, decode
tokens/sec, KV-cache per-device bytes) — the serving capacity-planning
numbers the scenario axis exists to produce.

    PYTHONPATH=src python benchmarks/bench_serving.py --smoke
    PYTHONPATH=src python benchmarks/bench_serving.py --update-goldens
"""
from __future__ import annotations

import argparse
import os
import sys
import tempfile
import time

from repro.core import AnalyticalProvider, get_cluster
from repro.core.simulator import DistSim
from repro.search.report import format_table
from repro.store import ServeQuery
from repro.validate import run_sweep, serving_matrix
from repro.validate.report import (format_validation_report, save)

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), os.pardir, "tests",
                           "goldens", "validation_serving.json")


def serve_gate(cells, cluster: str) -> dict:
    """serve()/serve_batch() answers must be bit-identical to
    per-engine simulate() for every serving cell — cold store, then
    again from the warm store (zero evaluations)."""
    queries = [ServeQuery(c.arch, c.strategy, global_batch=c.global_batch,
                          seq=c.seq, smoke=c.smoke, cluster=cluster,
                          scenario=c.scenario) for c in cells]
    expected = []
    for c in cells:
        sim = DistSim(c.config(), c.strategy, c.global_batch, c.seq,
                      AnalyticalProvider(get_cluster(cluster)),
                      scenario=c.scenario)
        r = sim.simulate()
        expected.append((r.batch_time, r.throughput_tokens()))

    with tempfile.TemporaryDirectory() as d:
        store = os.path.join(d, "store")
        cold = DistSim.serve_batch(queries, store)
        warm_server = DistSim.serve(store)
        warm = warm_server.answer_batch(queries)
        snap = warm_server.snapshot()
    evals = sum(c["evaluations"] for c in snap["clusters"].values())
    mismatches = [
        q.arch + "/" + q.scenario.label()
        for q, a, w, (bt, tok) in zip(queries, cold, warm, expected)
        if not (a.batch_time == w.batch_time == bt
                and a.throughput_tokens == w.throughput_tokens == tok)]
    return {"cells": len(cells), "mismatches": mismatches,
            "warm_evaluations": evals,
            "bit_identical": not mismatches,
            "warm_zero_eval": evals == 0,
            "answers": [{"label": c.label(),
                         "batch_time": a.batch_time,
                         "tokens_per_s": a.throughput_tokens,
                         "kv_cache_bytes": a.kv_cache_bytes,
                         "hbm_headroom": a.hbm_headroom}
                        for c, a in zip(cells, cold)]}


def throughput_table(gate: dict) -> str:
    rows = [[a["label"], f"{a['batch_time'] * 1e3:.4f}",
             f"{a['tokens_per_s']:.3e}", f"{a['kv_cache_bytes']:.3e}",
             f"{a['hbm_headroom'] / 2**30:.1f}"]
            for a in gate["answers"]]
    return "\n".join(format_table(
        ["cell", "step_ms", "tok/s", "kv_bytes/dev", "headroom_GiB"],
        rows, aligns=("<", ">", ">", ">", ">")))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="the serving matrix (the default and only "
                         "matrix for now)")
    ap.add_argument("--seeds", default="0,1,2")
    ap.add_argument("--cluster", default="a40-cluster")
    ap.add_argument("--jitter", type=float, default=0.025)
    ap.add_argument("--out", default="serving_report.json",
                    help="report path ('' to skip writing)")
    ap.add_argument("--update-goldens", action="store_true",
                    help=f"rewrite {os.path.normpath(GOLDEN_PATH)}")
    args = ap.parse_args()
    if args.update_goldens and (
            args.seeds != "0,1,2" or args.cluster != "a40-cluster"
            or args.jitter != 0.025):
        ap.error("--update-goldens pins default seeds/cluster/jitter — "
                 "tests/test_serving.py hard-codes them")

    cells = serving_matrix()
    seeds = tuple(int(s) for s in args.seeds.split(","))

    t0 = time.perf_counter()
    result = run_sweep(cells, cluster=args.cluster, seeds=seeds,
                       jitter_sigma=args.jitter)
    wall = time.perf_counter() - t0
    print(format_validation_report(result))
    print(f"\nsweep wall time: {wall:.2f}s")

    gate = serve_gate(cells, args.cluster)
    print("\nserving throughput (predicted, serve path):")
    print(throughput_table(gate))
    print(f"\nserve-vs-simulate: {gate['cells']} cells, "
          f"bit_identical={gate['bit_identical']}, "
          f"warm_evaluations={gate['warm_evaluations']}")

    if args.out:
        save(result, args.out)
        print(f"wrote {args.out}")
    if args.update_goldens:
        save(result, os.path.normpath(GOLDEN_PATH))
        print(f"wrote {os.path.normpath(GOLDEN_PATH)}")

    ok = result.passed and gate["bit_identical"] and gate["warm_zero_eval"]
    if not ok:
        print("FAILED:", [c.cell.label() for c in result.failures],
              gate["mismatches"],
              f"warm_evaluations={gate['warm_evaluations']}")
    sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()
