"""Layer-level model graph: the partitioner input to DistSim.

The paper leverages Megatron-LM's partitioner to obtain per-device
sub-models; we derive the same information directly from ``ArchConfig``:
a list of ``LayerSpec``s, each describing its GEMMs (full, unsharded
dims), parameter bytes, activation-output bytes and the collectives each
parallelism level induces. ``repro.core.events`` shards these by the
strategy and deduplicates into events.

All byte counts assume bf16 (2 bytes) unless stated.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

from repro.configs.base import ArchConfig

BYTES = 2  # bf16


@dataclasses.dataclass(frozen=True)
class GEMM:
    m: int
    n: int
    k: int

    @property
    def flops(self) -> float:
        return 2.0 * self.m * self.n * self.k

    @property
    def bytes(self) -> float:
        return BYTES * (self.m * self.k + self.k * self.n + self.m * self.n)

    def shard(self, mp: int, axis: str = "n") -> "GEMM":
        """Tensor-parallel sharding along n (column) or k (row) or m."""
        if mp == 1:
            return self
        if axis == "n":
            return GEMM(self.m, max(1, self.n // mp), self.k)
        if axis == "k":
            return GEMM(self.m, self.n, max(1, self.k // mp))
        return GEMM(max(1, self.m // mp), self.n, self.k)


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    name: str                    # e.g. "block", "embed", "head"
    kind: str                    # embed|attn_ffn|ssm|moe|head|enc_block|dec_block
    count: int                   # how many identical layers of this spec
    gemms: Tuple[GEMM, ...]      # forward GEMMs per microbatch (full dims)
    # (gemm, shard_axis) — which dim MP splits; len == len(gemms)
    shard_axes: Tuple[str, ...]
    param_bytes: float           # full (unsharded) parameter bytes
    act_bytes: float             # output activation bytes per microbatch
    # activation bytes all-reduced by TP per microbatch forward pass
    tp_allreduce_bytes: float = 0.0
    # bytes exchanged all-to-all by EP per microbatch forward pass
    ep_alltoall_bytes: float = 0.0
    mp_shardable: bool = True    # False → replicated under MP (e.g. norms)
    # decode scenario: persistent-state bytes (KV cache / SSM state)
    # streamed from HBM per forward pass — emitted as an ``hbm`` event
    kv_read_bytes: float = 0.0

    @property
    def fwd_flops(self) -> float:
        return sum(g.flops for g in self.gemms)

    @property
    def bwd_flops(self) -> float:
        return 2.0 * self.fwd_flops   # dgrad + wgrad


def _attn_gemms(cfg: ArchConfig, t: int, s: int, b: int,
                kv_len: Optional[int] = None):
    """Attention GEMMs for t=b*s query tokens against kv_len keys."""
    d, hd = cfg.d_model, cfg.head_dim
    kv = kv_len if kv_len is not None else s
    if cfg.sliding_window is not None:
        kv = min(kv, cfg.sliding_window)
    gemms = [
        GEMM(t, cfg.n_heads * hd, d),          # q proj   (col)
        GEMM(t, cfg.n_kv_heads * hd, d),       # k proj   (col)
        GEMM(t, cfg.n_kv_heads * hd, d),       # v proj   (col)
        GEMM(b * cfg.n_heads * s, kv, hd),     # scores   (head-sharded → m)
        GEMM(b * cfg.n_heads * s, hd, kv),     # att @ v  (head-sharded → m)
        GEMM(t, d, cfg.n_heads * hd),          # out proj (row)
    ]
    axes = ("n", "n", "n", "m", "m", "k")
    return gemms, axes


def _ffn_gemms(cfg: ArchConfig, t: int):
    d = cfg.d_model
    if cfg.moe is not None:
        e, k, f = cfg.moe.n_experts, cfg.moe.top_k, cfg.moe.d_ff_expert
        te = int(t * k * cfg.moe.capacity_factor)   # routed tokens (total)
        gemms = [
            GEMM(t, e, d),                     # router (replicated)
            GEMM(te, f, d),                    # gate  (expert-sharded → m)
            GEMM(te, f, d),                    # up
            GEMM(te, d, f),                    # down
        ]
        axes = ("m", "m", "m", "m")            # EP shards routed tokens
        return gemms, axes
    if cfg.mlp_gelu:
        return [GEMM(t, cfg.d_ff, d), GEMM(t, d, cfg.d_ff)], ("n", "k")
    return ([GEMM(t, cfg.d_ff, d), GEMM(t, cfg.d_ff, d),
             GEMM(t, d, cfg.d_ff)], ("n", "n", "k"))


def _ssm_gemms(cfg: ArchConfig, t: int, b: int, s: int):
    d = cfg.d_model
    sc = cfg.ssm
    di = sc.expand * d
    n = sc.d_state
    nh = di // sc.head_dim
    q = min(sc.chunk, s)
    nc = max(1, s // q)
    gemms = [
        GEMM(t, 2 * di + 2 * n + nh, d),       # in_proj (col)
        GEMM(b * nc * q, q, n),                # C B^T scores
        GEMM(b * nc * q, di, q),               # Y_diag
        GEMM(b * nc * di, n, q),               # chunk states
        GEMM(b * nc * q, di, n),               # Y_off
        GEMM(t, d, di),                        # out_proj (row)
    ]
    axes = ("n", "m", "n", "m", "n", "k")
    return gemms, axes


def _block_params(cfg: ArchConfig):
    """dict(attn=, ffn_moe=, ffn_dense=, ssm=) parameter bytes per layer."""
    d, hd = cfg.d_model, cfg.head_dim if cfg.n_heads else 0
    attn = 0.0
    if cfg.n_heads:
        attn = BYTES * d * hd * (cfg.n_heads + 2 * cfg.n_kv_heads
                                 + cfg.n_heads)
    ffn_moe = 0.0
    if cfg.moe is not None:
        f = cfg.moe.d_ff_expert
        ffn_moe = BYTES * (d * cfg.moe.n_experts
                           + cfg.moe.n_experts * 3 * d * f)
    if cfg.mlp_gelu:
        ffn_dense = BYTES * 2 * d * cfg.d_ff
    elif cfg.d_ff:
        ffn_dense = BYTES * 3 * d * cfg.d_ff
    else:
        ffn_dense = 0.0
    ssm = 0.0
    if cfg.ssm is not None:
        sc = cfg.ssm
        di = sc.expand * d
        nh = di // sc.head_dim
        ssm = BYTES * (d * (2 * di + 2 * sc.d_state + nh) + di * d
                       + sc.d_conv * (di + 2 * sc.d_state))
    return {"attn": attn, "ffn_moe": ffn_moe, "ffn_dense": ffn_dense,
            "ssm": ssm}


def _ffn_layer_bytes(cfg: ArchConfig, pb, active_only=False):
    """(moe_layer_ffn_bytes, dense_layer_ffn_bytes, n_moe, n_dense) totals."""
    if cfg.moe is None:
        return 0.0, pb["ffn_dense"], 0, cfg.n_layers
    n_moe = cfg.n_layers // cfg.moe_period
    n_dense = cfg.n_layers - n_moe
    moe_b = pb["ffn_moe"]
    if active_only:
        f = cfg.moe.d_ff_expert
        moe_b = BYTES * (cfg.d_model * cfg.moe.n_experts
                         + cfg.moe.top_k * 3 * cfg.d_model * f)
    return moe_b, pb["ffn_dense"], n_moe, n_dense


def build_graph(cfg: ArchConfig, batch: int, seq: int) -> List[LayerSpec]:
    """Layer graph for one microbatch of (batch, seq)."""
    t = batch * seq
    d = cfg.d_model
    act = BYTES * t * d
    pb = _block_params(cfg)
    attn_pb, ssm_pb = pb["attn"], pb["ssm"]
    ffn_pb = pb["ffn_moe"] if cfg.moe is not None else pb["ffn_dense"]
    layers: List[LayerSpec] = []

    emb_pb = BYTES * cfg.vocab * d
    layers.append(LayerSpec("embed", "embed", 1, (), (), emb_pb, act,
                            mp_shardable=False))

    ep_bytes = 0.0
    if cfg.moe is not None:
        # dispatch + combine of routed tokens
        ep_bytes = 2 * BYTES * t * cfg.moe.top_k * d

    if cfg.family == "ssm":
        g, a = _ssm_gemms(cfg, t, batch, seq)
        layers.append(LayerSpec("ssm_block", "ssm", cfg.n_layers, tuple(g), a,
                                ssm_pb, act, tp_allreduce_bytes=act))
    elif cfg.hybrid_period:
        n_attn = len(cfg.attn_layer_indices())
        moe_b, dense_b, n_moe, _ = _ffn_layer_bytes(cfg, pb)
        n_ssm_moe = max(0, n_moe - n_attn)     # attn layers take MoE slots
        n_ssm_dense = cfg.n_layers - n_attn - n_ssm_moe
        ga, aa = _attn_gemms(cfg, t, seq, batch)
        gf, af = _ffn_gemms(cfg, t)            # MoE ffn gemms
        layers.append(LayerSpec(
            "attn_block", "attn_ffn", n_attn, tuple(ga + gf), aa + af,
            attn_pb + moe_b, act, tp_allreduce_bytes=2 * act,
            ep_alltoall_bytes=ep_bytes))
        gs, as_ = _ssm_gemms(cfg, t, batch, seq)
        if n_ssm_moe:
            layers.append(LayerSpec(
                "ssm_moe_block", "ssm", n_ssm_moe, tuple(gs + gf), as_ + af,
                ssm_pb + moe_b, act, tp_allreduce_bytes=2 * act,
                ep_alltoall_bytes=ep_bytes))
        if n_ssm_dense:
            d_ff_gemms = ([GEMM(t, cfg.d_ff, d), GEMM(t, cfg.d_ff, d),
                           GEMM(t, d, cfg.d_ff)], ("n", "n", "k"))
            layers.append(LayerSpec(
                "ssm_dense_block", "ssm", n_ssm_dense,
                tuple(gs + d_ff_gemms[0]), as_ + d_ff_gemms[1],
                ssm_pb + dense_b, act, tp_allreduce_bytes=2 * act))
    elif cfg.enc_dec:
        ga, aa = _attn_gemms(cfg, t // 2, seq // 2, batch)
        gf, af = _ffn_gemms(cfg, t // 2)
        layers.append(LayerSpec(
            "enc_block", "attn_ffn", cfg.n_layers, tuple(ga + gf), aa + af,
            attn_pb + ffn_pb, act / 2, tp_allreduce_bytes=act))
        gc, ac = _attn_gemms(cfg, t // 2, seq // 2, batch, kv_len=seq // 2)
        layers.append(LayerSpec(
            "dec_block", "attn_ffn", cfg.n_layers,
            tuple(ga + gc + gf), aa + ac + af,
            2 * attn_pb + ffn_pb, act / 2, tp_allreduce_bytes=1.5 * act))
    else:
        ga, aa = _attn_gemms(cfg, t, seq, batch)
        gf, af = _ffn_gemms(cfg, t)
        layers.append(LayerSpec(
            "block", "attn_ffn", cfg.n_layers, tuple(ga + gf), aa + af,
            attn_pb + ffn_pb, act, tp_allreduce_bytes=2 * act,
            ep_alltoall_bytes=ep_bytes))

    head_pb = 0.0 if cfg.tie_embeddings else BYTES * d * cfg.vocab
    layers.append(LayerSpec("head", "head", 1,
                            (GEMM(t if not cfg.enc_dec else t // 2,
                                  cfg.vocab, d),),
                            ("n",), head_pb, BYTES * t * 4))
    return layers


# --------------------------------------------------------------------------
# decode scenario: seq=1 autoregressive graph + persistent-state memory
# --------------------------------------------------------------------------

def _kv_layer_bytes(cfg: ArchConfig, slots: int, kv_len: int) -> float:
    """KV-cache bytes one attention layer holds (and a decode step
    streams from HBM) for ``slots`` concurrent requests."""
    kv = kv_len
    if cfg.sliding_window is not None:
        kv = min(kv, cfg.sliding_window)
    return 2.0 * BYTES * slots * kv * cfg.n_kv_heads * cfg.head_dim


def _ssm_state_bytes(cfg: ArchConfig, slots: int) -> float:
    """Recurrent + conv state bytes per SSM layer (fp32 state)."""
    sc = cfg.ssm
    di = sc.expand * cfg.d_model
    return 4.0 * slots * (di * sc.d_state + sc.d_conv * (di + 2 * sc.d_state))


def _state_layer_counts(cfg: ArchConfig) -> Tuple[int, int]:
    """(attention layers holding KV cache, SSM layers holding state)."""
    if cfg.family == "ssm":
        return 0, cfg.n_layers
    if cfg.hybrid_period:
        n_attn = len(cfg.attn_layer_indices())
        return n_attn, cfg.n_layers - n_attn
    return cfg.n_layers, 0


def kv_cache_bytes(cfg: ArchConfig, slots: int, kv_len: int) -> float:
    """Total persistent decode state (KV cache + SSM state) across the
    whole model for ``slots`` concurrent requests at context ``kv_len``
    — the serving entry in the HBM memory model."""
    if cfg.enc_dec:
        raise ValueError("decode state model does not cover enc_dec models")
    n_attn, n_ssm = _state_layer_counts(cfg)
    total = n_attn * _kv_layer_bytes(cfg, slots, kv_len)
    if n_ssm:
        total += n_ssm * _ssm_state_bytes(cfg, slots)
    return total


def build_decode_graph(cfg: ArchConfig, slots: int, kv_len: int
                       ) -> List[LayerSpec]:
    """Layer graph for ONE autoregressive decode step: ``slots``
    concurrent requests, one query token each, attending to ``kv_len``
    cached keys. Each block carries ``kv_read_bytes`` — the HBM traffic
    of reading its KV cache / SSM state — which becomes an ``hbm``
    event in the composed stage."""
    if cfg.enc_dec:
        raise ValueError("decode scenario does not support enc_dec models")
    t = slots                       # one token per slot
    b = slots
    d = cfg.d_model
    act = BYTES * t * d
    pb = _block_params(cfg)
    attn_pb, ssm_pb = pb["attn"], pb["ssm"]
    ffn_pb = pb["ffn_moe"] if cfg.moe is not None else pb["ffn_dense"]
    layers: List[LayerSpec] = []

    emb_pb = BYTES * cfg.vocab * d
    layers.append(LayerSpec("embed", "embed", 1, (), (), emb_pb, act,
                            mp_shardable=False))

    ep_bytes = 0.0
    if cfg.moe is not None:
        ep_bytes = 2 * BYTES * t * cfg.moe.top_k * d

    if cfg.family == "ssm":
        g, a = _ssm_gemms(cfg, t, b, 1)
        layers.append(LayerSpec(
            "ssm_block", "ssm", cfg.n_layers, tuple(g), a, ssm_pb, act,
            tp_allreduce_bytes=act,
            kv_read_bytes=_ssm_state_bytes(cfg, slots)))
    elif cfg.hybrid_period:
        n_attn = len(cfg.attn_layer_indices())
        moe_b, dense_b, n_moe, _ = _ffn_layer_bytes(cfg, pb)
        n_ssm_moe = max(0, n_moe - n_attn)
        n_ssm_dense = cfg.n_layers - n_attn - n_ssm_moe
        kv_rd = _kv_layer_bytes(cfg, slots, kv_len)
        ssm_rd = _ssm_state_bytes(cfg, slots)
        ga, aa = _attn_gemms(cfg, t, 1, b, kv_len=kv_len)
        gf, af = _ffn_gemms(cfg, t)
        layers.append(LayerSpec(
            "attn_block", "attn_ffn", n_attn, tuple(ga + gf), aa + af,
            attn_pb + moe_b, act, tp_allreduce_bytes=2 * act,
            ep_alltoall_bytes=ep_bytes, kv_read_bytes=kv_rd))
        gs, as_ = _ssm_gemms(cfg, t, b, 1)
        if n_ssm_moe:
            layers.append(LayerSpec(
                "ssm_moe_block", "ssm", n_ssm_moe, tuple(gs + gf), as_ + af,
                ssm_pb + moe_b, act, tp_allreduce_bytes=2 * act,
                ep_alltoall_bytes=ep_bytes, kv_read_bytes=ssm_rd))
        if n_ssm_dense:
            d_ff_gemms = ([GEMM(t, cfg.d_ff, d), GEMM(t, cfg.d_ff, d),
                           GEMM(t, d, cfg.d_ff)], ("n", "n", "k"))
            layers.append(LayerSpec(
                "ssm_dense_block", "ssm", n_ssm_dense,
                tuple(gs + d_ff_gemms[0]), as_ + d_ff_gemms[1],
                ssm_pb + dense_b, act, tp_allreduce_bytes=2 * act,
                kv_read_bytes=ssm_rd))
    else:
        ga, aa = _attn_gemms(cfg, t, 1, b, kv_len=kv_len)
        gf, af = _ffn_gemms(cfg, t)
        layers.append(LayerSpec(
            "block", "attn_ffn", cfg.n_layers, tuple(ga + gf), aa + af,
            attn_pb + ffn_pb, act, tp_allreduce_bytes=2 * act,
            ep_alltoall_bytes=ep_bytes,
            kv_read_bytes=_kv_layer_bytes(cfg, slots, kv_len)))

    head_pb = 0.0 if cfg.tie_embeddings else BYTES * d * cfg.vocab
    layers.append(LayerSpec("head", "head", 1, (GEMM(t, cfg.vocab, d),),
                            ("n",), head_pb, BYTES * t * 4))
    return layers


# --------------------------------------------------------------------------
# parameter counting (used by ArchConfig.n_params and the roofline)
# --------------------------------------------------------------------------

def count_params(cfg: ArchConfig, active_only: bool = False) -> int:
    pb = _block_params(cfg)
    attn_pb, ssm_pb = pb["attn"], pb["ssm"]
    moe_b, dense_b, n_moe, n_dense = _ffn_layer_bytes(cfg, pb, active_only)
    total = 0.0
    if cfg.family == "ssm":
        total = ssm_pb * cfg.n_layers
    elif cfg.hybrid_period:
        n_attn = len(cfg.attn_layer_indices())
        n_ssm_moe = max(0, n_moe - n_attn)
        n_ssm_dense = cfg.n_layers - n_attn - n_ssm_moe
        total = (n_attn * (attn_pb + moe_b)
                 + n_ssm_moe * (ssm_pb + moe_b)
                 + n_ssm_dense * (ssm_pb + dense_b))
    elif cfg.enc_dec:
        ffn = moe_b if cfg.moe is not None else dense_b
        total = ((attn_pb + ffn) * cfg.n_layers
                 + (2 * attn_pb + ffn) * cfg.n_layers)
    else:
        total = n_moe * (attn_pb + moe_b) + n_dense * (attn_pb + dense_b)
    total += BYTES * cfg.vocab * cfg.d_model
    if not cfg.tie_embeddings:
        total += BYTES * cfg.d_model * cfg.vocab
    return int(total / BYTES)


def model_flops_per_token(cfg: ArchConfig) -> float:
    """The 6N approximation term (N = active params) for §Roofline."""
    return 6.0 * count_params(cfg, active_only=True)
