"""Workload scenarios: the axis that generalizes DistSim beyond the
training step.

The event/timeline machinery (profiled events composed by strategy
hierarchy, dependency-driven placement) is not training-specific —
DistIR applies the same IR simulation to inference distribution. A
:class:`Scenario` names the workload whose event graph is being built
and carries its scenario-specific parameters:

* :class:`TrainStep` — the paper's workload: fwd+bwd per microbatch,
  DP gradient sync, optimizer step. The default everywhere; every
  existing call path is bit-identical to the pre-scenario code.
* :class:`Prefill` — inference prompt processing: one full-sequence
  forward per pipelined request (``Strategy.microbatches`` requests),
  no backward, no gradient sync, no optimizer.
* :class:`Decode` — autoregressive serving: ``steps`` seq=1 iterations
  over a batch of concurrent slots, each attention layer reading its
  KV cache from HBM (an explicit ``hbm`` event) and each step's first
  stage waiting on the previous step's sampled-token feedback from the
  last stage (plus optional per-step ``arrivals`` floors — the
  continuous-batching model: a step cannot start before the request
  traffic that fills it has arrived).

Scenarios are frozen (hashable) dataclasses: they participate directly
in engine/build-cache/store content addresses. ``to_dict`` /
:func:`scenario_from_dict` give them the same JSON round-trip surface
as :class:`~repro.core.events.Strategy`.
"""
from __future__ import annotations

import dataclasses
from typing import ClassVar, Dict, Tuple


@dataclasses.dataclass(frozen=True)
class Scenario:
    """Base workload scenario (see module docstring). Subclasses set
    ``kind`` and override the derivation hooks they change."""

    kind: ClassVar[str] = "train"

    @property
    def is_train(self) -> bool:
        return self.kind == "train"

    # ---- derivation hooks (duck-typed over Strategy) ----

    def microbatch_size(self, strat, global_batch: int) -> int:
        """Samples per pipelined unit of work — delegates to the ONE
        train formula; :class:`Decode` reinterprets it as slot count."""
        return strat.microbatch_size(global_batch)

    def task_count(self, strat) -> int:
        """Pipelined work units per iteration (schedule's ``m``)."""
        return strat.microbatches

    def tokens(self, global_batch: int, seq: int) -> float:
        """Tokens processed per simulated iteration (throughput
        numerator): train/prefill push the full sequence."""
        return float(global_batch * seq)

    def kv_len(self, seq: int) -> int:
        """KV-cache context length (0 = no cache term)."""
        return 0

    def stripped(self) -> "Scenario":
        """The scenario modulo task count / arrival floors — the part
        an :class:`~repro.core.engine.EngineBuild` (and therefore its
        store content address) actually depends on."""
        return self

    def label(self) -> str:
        return self.kind

    # ---- JSON round-trip (reports, goldens, store keys) ----

    def to_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d["kind"] = self.kind
        return d


@dataclasses.dataclass(frozen=True)
class TrainStep(Scenario):
    """The paper's training step (fwd+bwd, DP sync, optimizer)."""

    kind: ClassVar[str] = "train"


@dataclasses.dataclass(frozen=True)
class Prefill(Scenario):
    """Full-sequence forward per request; requests pipeline through
    the stages exactly like training microbatches (forward only)."""

    kind: ClassVar[str] = "prefill"


@dataclasses.dataclass(frozen=True)
class Decode(Scenario):
    """``steps`` autoregressive seq=1 iterations over a slot batch.

    ``context`` is the KV-cache length each query attends to (0 = use
    the sim's ``seq``). ``arrivals`` are optional per-step earliest
    start times: step ``t``'s first stage waits on
    ``max(arrivals[t], previous step's token feedback)`` — the
    per-slot-arrival dependency that models continuous batching.
    """

    kind: ClassVar[str] = "decode"
    steps: int = 8
    context: int = 0
    arrivals: Tuple[float, ...] = ()

    def __post_init__(self):
        # tolerate lists (JSON round-trip) while staying hashable
        if not isinstance(self.arrivals, tuple):
            object.__setattr__(self, "arrivals", tuple(self.arrivals))
        if self.steps < 1:
            raise ValueError(f"Decode.steps must be >= 1, got {self.steps}")

    def microbatch_size(self, strat, global_batch: int) -> int:
        # concurrent decode slots per pipeline replica — decode has no
        # microbatch accumulation axis
        return max(1, global_batch // strat.dp)

    def task_count(self, strat) -> int:
        return self.steps

    def tokens(self, global_batch: int, seq: int) -> float:
        # one token per slot per autoregressive step
        return float(global_batch * self.steps)

    def kv_len(self, seq: int) -> int:
        return self.context if self.context else seq

    def stripped(self) -> "Decode":
        return dataclasses.replace(self, steps=1, arrivals=())

    def label(self) -> str:
        out = f"decode{self.steps}"
        if self.context:
            out += f"@{self.context}"
        return out


#: the default scenario — every pre-scenario call path.
TRAIN = TrainStep()

_KINDS = {"train": TrainStep, "prefill": Prefill, "decode": Decode}


def scenario_from_dict(d) -> Scenario:
    """Inverse of :meth:`Scenario.to_dict`; ``None`` (a report written
    before scenarios existed) loads as :data:`TRAIN`."""
    if d is None:
        return TRAIN
    if isinstance(d, Scenario):
        return d
    d = dict(d)
    kind = d.pop("kind", "train")
    try:
        cls = _KINDS[kind]
    except KeyError:
        raise ValueError(
            f"unknown scenario kind {kind!r}; have {sorted(_KINDS)}"
        ) from None
    from repro.core.serde import dataclass_from_dict
    return dataclass_from_dict(cls, d)
