"""Hierarchical modeling (paper §4.3, Algorithm 1).

Builds the full-cluster timeline bottom-up:

  1. MP level   — each layer becomes a ComposedEvent (sharded compute +
                  TP all-reduce (+ EP all-to-all)); times attached from the
                  (deduplicated) event profile.
  2. PP level   — layers → stages (or vpp virtual chunks); the pipeline
                  schedule's task lists are placed by the event-flow
                  engine's dependency-driven ready-queue: a task starts at
                  max(device free, input arrival) — exactly the paper's
                  ``first_available`` rule.
  3. DP level   — the (stage x microbatch) timeline is replicated DP
                  times; a gradient all-reduce (or ZeRO-1 reduce-scatter +
                  all-gather) synchronizes replicas at the end, followed
                  by the optimizer step.

The same constructor serves the replay oracle (``jitter_sigma > 0``):
per-instance event times are drawn around the profiled means and
per-device straggler/clock effects are added, which reproduces the
paper's observed error sources without owning the 16-GPU cluster.

The heavy lifting lives in :mod:`repro.core.engine`; ``construct_timeline``
is a thin compatibility wrapper that builds an :class:`EventFlowEngine`
per call. Hold an engine directly (``DistSim`` does) to amortize the
per-strategy precomputation across predict + multi-seed replay runs.
"""
from __future__ import annotations

from typing import List, Optional

from repro.configs.base import ArchConfig
from repro.core.costmodel import ClusterSpec
from repro.core.engine import EventFlowEngine
from repro.core.events import (ComposedEvent, Stage, Strategy,
                               flatten_layers, layer_composed_events,
                               partition_stages)
from repro.core.profiler import Provider
from repro.core.scenario import TRAIN, Scenario
from repro.core.timeline import Timeline


def build_positions(cfg: ArchConfig, strat: Strategy, microbatch: int,
                    seq: int, cluster: ClusterSpec,
                    scenario: Scenario = TRAIN) -> List[Stage]:
    """Stages for pp*vpp pipeline positions (vpp virtual chunks/device).

    Serving scenarios are forward-only (``bwd`` stays an empty bundle),
    use the *balanced* partition (an empty pipeline stage is merely
    wasteful in training but would stall every autoregressive step in
    decode), and — for decode — mark the last stage with the sampled-
    token feedback payload it sends back to stage 0 between steps.
    """
    if scenario.is_train:
        layers = flatten_layers(cfg, microbatch, seq)
        stages = partition_stages(layers, strat.pp * strat.vpp)
    else:
        if strat.vpp != 1:
            raise ValueError(
                f"scenario {scenario.label()!r} supports vpp=1 only "
                f"(got vpp={strat.vpp})")
        layers = flatten_layers(cfg, microbatch, seq, scenario=scenario)
        stages = partition_stages(layers, strat.pp, balanced=True)
    for st in stages:
        fwd, bwd = [], []
        for l in st.layers:
            fwd.extend(layer_composed_events(
                l, strat.mp, cluster.devices_per_island, "fwd").events)
            if scenario.is_train:
                bwd.extend(layer_composed_events(
                    l, strat.mp, cluster.devices_per_island, "bwd").events)
        st.fwd = ComposedEvent(f"pos{st.index}:fwd", fwd)
        st.bwd = ComposedEvent(f"pos{st.index}:bwd", bwd)
    if scenario.kind == "decode" and stages:
        # sampled token ids (int32 per slot) fed back to stage 0
        stages[-1].feedback_bytes = 4.0 * microbatch
    return stages


def construct_timeline(cfg: ArchConfig, strat: Strategy, global_batch: int,
                       seq: int, provider: Provider,
                       jitter_sigma: float = 0.0,
                       straggler_sigma: float = 0.0,
                       clock_sigma: float = 0.0,
                       seed: Optional[int] = None,
                       positions: Optional[List[Stage]] = None) -> Timeline:
    """One-shot timeline construction (API-compatible with the seed)."""
    if positions is None:
        microbatch = max(1, global_batch // (strat.dp * strat.microbatches))
        positions = build_positions(cfg, strat, microbatch, seq,
                                    provider.cluster)
    engine = EventFlowEngine(positions, strat, provider)
    return engine.run(jitter_sigma=jitter_sigma,
                      straggler_sigma=straggler_sigma,
                      clock_sigma=clock_sigma, seed=seed)
