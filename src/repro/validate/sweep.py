"""Accuracy-sweep engine: predict() vs multi-seed replay() conformance.

The sweep runs DistSim's performance model against its discrete-event
replay oracle over a matrix of (model x schedule x hybrid strategy)
cells and gates each cell on the paper's §5 targets (<4% batch-time
error, <5% per-device activity error). Proteus/DistIR-style: the suite
exists so the event/timeline core can be refactored freely — any
fidelity drift trips the gate, not a reviewer's eyeball.

All cells on one cluster share a single profiling provider, so the
paper's unique-event dedup (Observation 1) applies across the whole
sweep: an event profiled for one cell is free for every later cell.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Union

from repro.configs.base import get_config, smoke_config
from repro.core.costmodel import A40_CLUSTER, ClusterSpec, get_cluster
from repro.core.events import Strategy
from repro.core.profiler import AnalyticalProvider, Provider
from repro.core.scenario import TRAIN, Decode, Prefill, Scenario
from repro.core.serde import dataclass_from_dict
from repro.core.simulator import DistSim
from repro.validate.build_cache import BuildCache
from repro.validate.metrics import (CellMetrics, aggregate, compare_batch,
                                    compare_timelines)


@dataclasses.dataclass(frozen=True)
class Thresholds:
    """Pass/fail budgets per metric. Defaults are the paper's §5
    headline targets plus looser caps on the secondary deltas."""
    batch_time: float = 0.04          # §5.2: <4% iteration-time error
    activity: float = 0.05            # §5.3: <5% per-device activity error
    stage: float = 0.10               # §5.4 timestamp error, worst stage
    utilization: float = 0.10
    # worst single replay seed — so one bad draw can't hide in the
    # seed-mean that `batch_time` gates (1.5x the mean budget)
    batch_time_worst: float = 0.06

    def violations(self, m: CellMetrics) -> List[str]:
        out = []
        if m.batch_time_error > self.batch_time:
            out.append("batch_time")
        if m.worst_batch_time_error > self.batch_time_worst:
            out.append("batch_time_worst")
        if m.activity_error_max > self.activity:
            out.append("activity")
        if m.stage_error_max > self.stage:
            out.append("stage")
        if m.utilization_delta_max > self.utilization:
            out.append("utilization")
        return out

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "Thresholds":
        return dataclass_from_dict(cls, d)


@dataclasses.dataclass(frozen=True)
class ValidationCell:
    """One sweep point: a model config under one hybrid strategy, in
    one scenario (training step by default; prefill/decode cells gate
    the serving event graphs against the same replay oracle)."""
    arch: str
    strategy: Strategy
    global_batch: int = 16
    seq: int = 512
    smoke: bool = False               # reduce the arch via smoke_config
    xfail: str = ""                   # known-bad reason; reported, not gated
    scenario: Scenario = TRAIN

    def label(self) -> str:
        arch = self.arch + ("~smoke" if self.smoke else "")
        sched = (f"/{self.strategy.schedule}:m{self.strategy.microbatches}"
                 if self.scenario.is_train
                 else f"/{self.scenario.label()}")
        return (f"{arch}/{self.strategy.label()}" + sched
                + (f":v{self.strategy.vpp}" if self.strategy.vpp > 1 else ""))

    def config(self):
        cfg = get_config(self.arch)
        return smoke_config(cfg) if self.smoke else cfg


@dataclasses.dataclass
class CellResult:
    cell: ValidationCell
    metrics: CellMetrics              # aggregated over seeds
    per_seed: List[CellMetrics]
    seeds: List[int]
    pred_batch_time: float
    replay_batch_times: List[float]
    violations: List[str]

    @property
    def passed(self) -> bool:
        return not self.violations

    @property
    def gates(self) -> bool:
        """Whether this cell participates in the pass/fail verdict."""
        return not self.cell.xfail


@dataclasses.dataclass
class SweepResult:
    cells: List[CellResult]
    thresholds: Thresholds
    cluster: str
    seeds: List[int]
    jitter_sigma: float

    @property
    def failures(self) -> List[CellResult]:
        return [c for c in self.cells if c.gates and not c.passed]

    @property
    def xpasses(self) -> List[CellResult]:
        """xfail cells that now pass — candidates for un-marking."""
        return [c for c in self.cells if not c.gates and c.passed]

    @property
    def passed(self) -> bool:
        return not self.failures


# --------------------------------------------------------------------------
# sweep matrices
# --------------------------------------------------------------------------

def _cell(arch, mp, pp, dp, m, schedule, vpp=1, gb=16, seq=512,
          smoke=False, xfail="", scenario=TRAIN) -> ValidationCell:
    return ValidationCell(
        arch, Strategy(mp=mp, pp=pp, dp=dp, microbatches=m,
                       schedule=schedule, vpp=vpp),
        global_batch=gb, seq=seq, smoke=smoke, xfail=xfail,
        scenario=scenario)


def smoke_matrix() -> List[ValidationCell]:
    """The CI gate: every model family x every schedule x dp/tp/pp mix,
    small enough to sweep in seconds on one CPU."""
    return [
        # gpt2_345m — dense decoder, all four schedules + pure DP
        _cell("gpt2_345m", 1, 2, 2, 4, "1f1b"),
        _cell("gpt2_345m", 1, 4, 1, 8, "gpipe"),
        _cell("gpt2_345m", 2, 2, 1, 4, "interleaved", vpp=2),
        _cell("gpt2_345m", 1, 2, 2, 4, "pipedream"),
        _cell("gpt2_345m", 1, 1, 4, 2, "1f1b"),
        # bert_large — dense encoder, tp+pp+dp hybrid
        _cell("bert_large", 2, 2, 2, 4, "1f1b"),
        _cell("bert_large", 1, 2, 2, 4, "gpipe"),
        # t5_large — encoder-decoder stage imbalance
        _cell("t5_large", 1, 2, 2, 4, "1f1b"),
        _cell("t5_large", 1, 4, 1, 8, "interleaved", vpp=2),
        # small MoE — EP all-to-all events under tp
        _cell("qwen3_moe_30b_a3b", 2, 2, 1, 4, "1f1b", smoke=True),
        _cell("qwen3_moe_30b_a3b", 1, 2, 2, 4, "gpipe", smoke=True),
    ]


def serving_matrix() -> List[ValidationCell]:
    """Serving-scenario gate: prefill + decode cells for the three
    serving-relevant families (VLM, SSM/attention hybrid, fine-grained
    MoE), smoke-reduced, gated at the same <4%/<5% thresholds as
    training. Decode cells include a continuous-batching variant
    (staggered per-slot arrivals) and a long-context KV read."""
    out: List[ValidationCell] = []
    for arch in ("qwen2_vl_72b", "jamba_v0_1_52b", "qwen3_moe_30b_a3b"):
        out.append(_cell(arch, 2, 2, 1, 4, "1f1b", gb=8, smoke=True,
                         scenario=Prefill()))
        out.append(_cell(arch, 1, 2, 2, 4, "1f1b", gb=8, smoke=True,
                         scenario=Decode(steps=8)))
    # continuous batching: slots arrive staggered mid-flight
    out.append(_cell("qwen3_moe_30b_a3b", 1, 2, 2, 4, "1f1b", gb=8,
                     smoke=True,
                     scenario=Decode(steps=6,
                                     arrivals=(0.0, 1e-4, 2e-4))))
    # long-context decode: KV read term dominates per-step time
    out.append(_cell("qwen2_vl_72b", 1, 1, 4, 2, "1f1b", gb=8,
                     smoke=True, scenario=Decode(steps=4, context=4096)))
    return out


def full_matrix() -> List[ValidationCell]:
    """Nightly-scale cross product (models x schedules x strategies);
    infeasible (batch-divisibility) combos are skipped. Extended with
    predict-scale scenario-diversity cells: full-size 52–145B models
    (dense, fine-grained MoE, SSM/attention hybrid, VLM) at 64–128
    device strategies — affordable because the 4 schedules of each
    (model, strategy) pair share one cached engine build and the sweep
    fans out across worker processes (``run_sweep(jobs=N)``)."""
    archs = [("gpt2_345m", False), ("bert_large", False),
             ("t5_large", False), ("qwen3_moe_30b_a3b", True)]
    strategies = [(1, 2, 2, 4), (2, 2, 2, 4), (1, 4, 1, 8), (2, 4, 1, 8),
                  (1, 1, 4, 2), (4, 2, 1, 4), (1, 2, 4, 4), (2, 1, 2, 4)]
    gb = 32
    out: List[ValidationCell] = []
    for arch, smoke in archs:
        for mp, pp, dp, m in strategies:
            if gb % (dp * m):
                continue
            for schedule in ("gpipe", "1f1b", "interleaved", "pipedream"):
                vpp = 2 if schedule == "interleaved" and pp > 1 else 1
                out.append(_cell(arch, mp, pp, dp, m, schedule, vpp=vpp,
                                 gb=gb, smoke=smoke))
    # predict-scale cells: full-size models, 64-128 devices
    big_archs = ["gpt_145b", "dbrx_132b", "jamba_v0_1_52b",
                 "qwen2_vl_72b"]
    big_strategies = [(8, 8, 2, 8), (2, 16, 2, 8)]
    for arch in big_archs:
        for mp, pp, dp, m in big_strategies:
            for schedule in ("gpipe", "1f1b", "interleaved", "pipedream"):
                vpp = 2 if schedule == "interleaved" else 1
                out.append(_cell(arch, mp, pp, dp, m, schedule, vpp=vpp,
                                 gb=64, seq=1024))
    return out


# --------------------------------------------------------------------------
# engine
# --------------------------------------------------------------------------

def run_cell(cell: ValidationCell, provider: Provider,
             seeds: Sequence[int] = (0, 1, 2),
             thresholds: Optional[Thresholds] = None,
             jitter_sigma: float = 0.025, batched: bool = True,
             cache: Optional[BuildCache] = None) -> CellResult:
    """One sweep point: one engine build, one batched replay over all
    seeds, array-native metrics (no ``Activity`` materialization).

    ``cache`` (a :class:`BuildCache` bound to ``provider``) serves the
    cell's engine content-addressed, so repeated (model, strategy)
    structure — e.g. the same pair under another schedule — skips the
    model-graph + event-mean rebuild; results are bit-identical either
    way. ``batched=False`` keeps the historical path — S sequential
    ``engine.run(seed=s)`` replays compared via materialized activity
    lists — as the differential baseline for
    ``tests/test_validation.py`` and the seed-scaling section of
    ``benchmarks/bench_timeline.py``.
    """
    thresholds = thresholds or Thresholds()
    sim = DistSim(cell.config(), cell.strategy, cell.global_batch,
                  cell.seq, provider,
                  scenario=getattr(cell, "scenario", TRAIN))
    if cache is not None:
        sim.use_engine(cache.engine_for(cell))
    if batched:
        pred_b = sim.simulate().batch
        rep_b = sim.simulate(seeds=seeds,
                             jitter_sigma=jitter_sigma).batch
        per_seed = compare_batch(pred_b, rep_b)
        pred_bt = float(pred_b.batch_times[0])
        replay_bts = [float(t) for t in rep_b.batch_times]
    else:
        # sequential differential baseline: one engine, one run() per
        # seed, activity-list comparison — deliberately NOT routed
        # through simulate() so it stays an independent oracle
        engine = sim.engine()
        pred_tl = engine.run()
        replay_tls = [engine.run(jitter_sigma=jitter_sigma, seed=s)
                      for s in seeds]
        per_seed = [compare_timelines(pred_tl, tl) for tl in replay_tls]
        pred_bt = pred_tl.batch_time
        replay_bts = [tl.batch_time for tl in replay_tls]
    metrics = aggregate(per_seed)
    return CellResult(
        cell=cell, metrics=metrics, per_seed=per_seed, seeds=list(seeds),
        pred_batch_time=pred_bt,
        replay_batch_times=replay_bts,
        violations=thresholds.violations(metrics))


def run_sweep(cells: Optional[Sequence[ValidationCell]] = None,
              cluster: Union[str, ClusterSpec, None] = None,
              seeds: Sequence[int] = (0, 1, 2),
              thresholds: Optional[Thresholds] = None,
              jitter_sigma: float = 0.025,
              provider: Optional[Provider] = None,
              batched: bool = True,
              cache: Union[bool, BuildCache] = True,
              jobs: int = 1,
              store=None) -> SweepResult:
    """Run the matrix; one shared provider = one event profile cache.

    ``cluster`` defaults to the provider's (or ``A40_CLUSTER`` when no
    provider is given); passing BOTH a cluster and a provider whose
    cluster disagrees raises ``ValueError`` — a silently-ignored
    cluster would sweep different hardware than asked.

    ``cache`` — ``True`` (default) shares one content-addressed
    :class:`BuildCache` across all cells (pass your own instance to
    keep it warm across *serial* sweeps, or ``False`` to rebuild per
    cell); either way the report is bit-identical. ``jobs > 1`` fans
    cells out across worker processes (:mod:`repro.validate.executor`)
    with per-worker provider shards, merged back so the report — and
    the provider's unique-event accounting — matches the serial sweep.
    Workers build their own caches (engines hold unpicklable state),
    so with ``jobs > 1`` a passed instance only accumulates the
    shards' hit/miss accounting — it is neither consulted nor warmed;
    pass ``store`` to share warm state across processes instead.

    ``store`` — a :class:`repro.store.ProfileStore` (or its directory
    path): profiled event times and engine builds are served from and
    persisted to disk, shared across sweeps, searches, executor
    workers and *processes*. With ``jobs > 1`` the workers open the
    store themselves instead of receiving the parent's pickled event
    cache. Store-served sweeps are bit-identical to cold runs.
    """
    if isinstance(cluster, str):
        cluster = get_cluster(cluster)
    cells = list(cells) if cells is not None else smoke_matrix()
    thresholds = thresholds or Thresholds()
    if provider is None and isinstance(cache, BuildCache):
        provider = cache.provider     # a warm cache implies its provider
    if (provider is not None and cluster is not None
            and provider.cluster != cluster):
        raise ValueError(
            f"cluster {cluster.name!r} disagrees with the provider's "
            f"{provider.cluster.name!r}; pass one or the other (the "
            f"provider's event times are profiled for ITS cluster)")
    provider = provider or AnalyticalProvider(cluster or A40_CLUSTER)
    if isinstance(cache, BuildCache) and cache.provider is not provider:
        raise ValueError("cache is bound to a different provider than "
                         "the sweep's")
    if jobs and jobs > 1:
        from repro.validate.executor import run_parallel
        results = run_parallel(
            cells, provider, seeds, thresholds, jitter_sigma, jobs=jobs,
            batched=batched, use_cache=bool(cache),
            cache_stats=cache.stats if isinstance(cache, BuildCache)
            else None, store=store)
    else:
        opened = None
        known = None
        if store is not None:
            from repro.store import (PersistentBuildCache, open_store)
            opened = open_store(store)
        if isinstance(cache, BuildCache):
            bc: Optional[BuildCache] = cache
            if opened is not None \
                    and not isinstance(cache, PersistentBuildCache):
                raise ValueError(
                    "store given alongside a plain BuildCache instance;"
                    " pass cache=True (a PersistentBuildCache is built"
                    " for you) or a PersistentBuildCache")
        elif cache:
            bc = (PersistentBuildCache(provider, opened)
                  if opened is not None else BuildCache(provider))
        else:
            bc = None
            if opened is not None:
                # cache-less store-served sweep: events still come
                # from / go back to disk
                opened.load_events(provider)
                known = set(provider.cache_snapshot())
        results = [run_cell(c, provider, seeds, thresholds, jitter_sigma,
                            batched=batched, cache=bc)
                   for c in cells]
        if opened is not None:
            if bc is not None:
                bc.flush()
            else:
                delta = {e: t
                         for e, t in provider.cache_snapshot().items()
                         if e not in known}
                if delta:
                    opened.save_events(provider, delta)
    return SweepResult(cells=results, thresholds=thresholds,
                       cluster=provider.cluster.name, seeds=list(seeds),
                       jitter_sigma=jitter_sigma)
