"""Cluster + analytical event cost model.

A ``ClusterSpec`` describes the interconnect hierarchy; two presets:

* ``V5E_POD``   — the deployment target (ICI torus intra-pod, DCN inter-pod).
* ``A40_CLUSTER`` — the paper's testbed shape (NVLink intra-node, IB
  inter-node), used by the paper-reproduction benchmarks so the error
  numbers are comparable with the published figures.

The all-reduce model is the paper's §4.2 extrapolation: a ring moves
2(N−1)/N · P bytes per device regardless of N, so a ≤8-way profile
extends to any N; we add the per-hop latency term that matters at small P.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

from repro.core.hw import ChipSpec, V5E, mxu_efficiency
from repro.core.modelgraph import GEMM


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    name: str
    chip: ChipSpec
    devices_per_island: int          # node (GPU) or pod (TPU)
    intra_bw: float                  # bytes/s per device, island-internal
    inter_bw: float                  # bytes/s per device, cross-island
    intra_latency: float
    inter_latency: float

    # dict round-trip matching Strategy's, so search reports serialize
    # clusters as full specs (custom clusters survive a report
    # round-trip; a registry name alone can't say what "tiny-a40" was)
    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict) -> "ClusterSpec":
        from repro.core.serde import dataclass_from_dict
        return dataclass_from_dict(cls, d)


V5E_POD = ClusterSpec(
    name="v5e-pod",
    chip=V5E,
    devices_per_island=256,
    intra_bw=V5E.ici_link_bw * V5E.ici_links_per_axis,   # 2 links/axis ring
    inter_bw=V5E.dcn_bw,
    intra_latency=V5E.ici_hop_latency,
    inter_latency=V5E.dcn_latency,
)

# A40 calibration: the paper trains with PyTorch eager; achieved GEMM
# throughput there is far below the 150 TF/s bf16 tensor-core peak.
# 37 TF/s (the fp32 tensor-core rate) reproduces the paper's absolute
# iteration times within ~2x, which is what an uncalibrated analytical
# provider can claim (MeasuredProvider exists for exact calibration).
_A40 = ChipSpec(name="a40", peak_flops_bf16=37e12, hbm_bw=696e9,
                hbm_bytes=48e9, op_overhead=4e-6)
A40_CLUSTER = ClusterSpec(
    name="a40-cluster",
    chip=_A40,
    devices_per_island=4,            # 4 GPUs per server (paper testbed)
    intra_bw=56e9,                   # PCIe/NVLink-ish effective
    inter_bw=12.5e9,                 # 100 Gb IB
    intra_latency=5e-6,
    inter_latency=15e-6,
)


#: name → spec registry, used by the multi-cluster search CLI surfaces
#: (``--clusters a40-cluster,v5e-pod``).
CLUSTERS = {c.name: c for c in (V5E_POD, A40_CLUSTER)}


def get_cluster(name: str) -> ClusterSpec:
    try:
        return CLUSTERS[name]
    except KeyError:
        raise ValueError(
            f"unknown cluster {name!r}; known: {sorted(CLUSTERS)}") from None


def gemm_time(g: GEMM, chip: ChipSpec) -> float:
    """Operator-level roofline with MXU efficiency curve."""
    eff = mxu_efficiency(g.m, g.n, g.k, chip)
    t_compute = g.flops / (chip.peak_flops_bf16 * eff)
    t_memory = g.bytes / chip.hbm_bw
    return max(t_compute, t_memory) + chip.op_overhead


def compute_time(gemms: Tuple[GEMM, ...], chip: ChipSpec) -> float:
    return sum(gemm_time(g, chip) for g in gemms)


def ring_hops(op: str, n_dev: int) -> int:
    """Per-device hop count of a ring collective on n_dev devices
    (all-reduce = reduce-scatter + all-gather, so twice the hops).
    Shared by :func:`collective_time` and the >8-way extrapolation in
    :meth:`repro.core.profiler.Provider._time`."""
    if op == "all_reduce":
        return 2 * (n_dev - 1)
    if op in ("all_gather", "reduce_scatter", "all_to_all"):
        return n_dev - 1
    raise ValueError(op)


def ring_volume_factor(op: str, n_dev: int) -> float:
    """Bytes moved per device as a fraction of the full tensor — the
    paper's §4.2 extrapolation quantity (2(N−1)/N for all-reduce),
    shared with the profiler's >8-way extrapolation."""
    if op == "all_reduce":
        return 2.0 * (n_dev - 1) / n_dev
    if op in ("all_gather", "reduce_scatter", "all_to_all"):
        return (n_dev - 1) / n_dev
    raise ValueError(op)


def collective_time(op: str, nbytes: float, n_dev: int,
                    cluster: ClusterSpec, scope: str = "intra") -> float:
    """Ring-based collective on n_dev devices.

    op ∈ {all_reduce, all_gather, reduce_scatter, all_to_all}.
    nbytes = FULL tensor size (pre-sharding for ag/rs conventions follows
    XLA: all_gather output, reduce_scatter input).
    """
    if n_dev <= 1:
        return 0.0
    bw = cluster.intra_bw if scope == "intra" else cluster.inter_bw
    lat = (cluster.intra_latency if scope == "intra"
           else cluster.inter_latency)
    vol = ring_volume_factor(op, n_dev) * nbytes
    hops = ring_hops(op, n_dev)
    return vol / bw + hops * lat


def p2p_time(nbytes: float, cluster: ClusterSpec,
             scope: str = "intra") -> float:
    bw = cluster.intra_bw if scope == "intra" else cluster.inter_bw
    lat = (cluster.intra_latency if scope == "intra"
           else cluster.inter_latency)
    return nbytes / bw + lat


def hbm_time(nbytes: float, cluster: ClusterSpec) -> float:
    """HBM-bandwidth-bound streaming read (decode KV cache / SSM state).

    No op_overhead term: the read overlaps the attention kernel launch
    it feeds; the bandwidth term is the part the roofline can't hide at
    seq=1.
    """
    return nbytes / cluster.chip.hbm_bw
