"""Encoder-decoder assembly (whisper-tiny backbone, T5).

Encoder: bidirectional self-attention blocks. Decoder: causal self-attention
+ cross-attention + FFN. ``n_layers`` means n encoder AND n decoder layers.
Positional encoding is RoPE for both stacks (DESIGN.md: performance-shape
equivalent to sinusoidal/relative-bias; the modality frontend is a stub).

Decode caches: ring-buffer self-attention KV + precomputed cross K/V.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models.layers import ModelOptions, DEFAULT_OPTIONS
from repro.models.lm import (_attn_shapes, _ffn_shapes, _attn_block,
                             _ffn_block, _init_tree, _chunked_ce)


def encdec_param_shapes(cfg: ArchConfig):
    enc = {**_attn_shapes(cfg), "ffn": _ffn_shapes(cfg)}
    dec = {**_attn_shapes(cfg), "cross": _attn_shapes(cfg),
           "ffn": _ffn_shapes(cfg)}
    return enc, dec


def init_params(cfg: ArchConfig, key: jax.Array,
                opts: ModelOptions = DEFAULT_OPTIONS):
    dtype = opts.dtype
    kemb, kenc, kdec = jax.random.split(key, 3)
    enc_sh, dec_sh = encdec_param_shapes(cfg)

    def stack(k, sh, n):
        base = _init_tree(k, sh, dtype)
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n,) + x.shape).copy(), base)

    params = {
        "embed": (jax.random.normal(kemb, (cfg.vocab, cfg.d_model),
                                    jnp.float32) * 0.02).astype(dtype),
        "enc_layers": stack(kenc, enc_sh, cfg.n_layers),
        "dec_layers": stack(kdec, dec_sh, cfg.n_layers),
        "enc_norm": jnp.ones((cfg.d_model,), dtype),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["head"] = (jax.random.normal(
            jax.random.fold_in(kemb, 1), (cfg.d_model, cfg.vocab),
            jnp.float32) * 0.02).astype(dtype)
    return params


def encode(cfg: ArchConfig, params, enc_x: jax.Array,
           opts: ModelOptions = DEFAULT_OPTIONS) -> jax.Array:
    """enc_x: (B,F,d) stub embeddings (audio) or embedded tokens."""
    b, f = enc_x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(f), (b, f))

    def body(h, lp):
        h = _attn_block(cfg, {k: v for k, v in lp.items() if k != "ffn"},
                        h, positions, opts, causal=False)
        h, _ = _ffn_block(cfg, lp["ffn"], h, opts)
        return L.constrain(h, opts), None

    body_fn = jax.checkpoint(body) if opts.remat else body
    h, _ = lax.scan(body_fn, enc_x, params["enc_layers"])
    return L.rmsnorm(h, params["enc_norm"])


def decode_train(cfg: ArchConfig, params, enc_out: jax.Array,
                 tokens: jax.Array, opts: ModelOptions = DEFAULT_OPTIONS):
    """Teacher-forced decoder forward → hidden (B,T,d)."""
    b, t = tokens.shape
    f = enc_out.shape[1]
    positions = jnp.broadcast_to(jnp.arange(t), (b, t))
    enc_pos = jnp.broadcast_to(jnp.arange(f), (b, f))
    x = params["embed"][tokens].astype(opts.dtype)

    def body(h, lp):
        h = _attn_block(cfg, {k: v for k, v in lp.items()
                              if k not in ("ffn", "cross")},
                        h, positions, opts, causal=True)
        h = _attn_block(cfg, lp["cross"], h, positions, opts, causal=False,
                        kv=(enc_out, enc_pos))
        h, _ = _ffn_block(cfg, lp["ffn"], h, opts)
        return L.constrain(h, opts), None

    body_fn = jax.checkpoint(body) if opts.remat else body
    h, _ = lax.scan(body_fn, x, params["dec_layers"])
    return h


def forward(cfg: ArchConfig, params, batch: Dict[str, jax.Array],
            opts: ModelOptions = DEFAULT_OPTIONS) -> jax.Array:
    enc_in = (batch["frame_embeds"].astype(opts.dtype) if cfg.audio_stub
              else params["embed"][batch["tokens_enc"]].astype(opts.dtype))
    enc_out = encode(cfg, params, enc_in, opts)
    h = decode_train(cfg, params, enc_out, batch["tokens"], opts)
    h = L.rmsnorm(h, params["final_norm"])
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    return jnp.einsum("bsd,dv->bsv", h, head)


def loss_fn(cfg: ArchConfig, params, batch, opts=DEFAULT_OPTIONS):
    enc_in = (batch["frame_embeds"].astype(opts.dtype) if cfg.audio_stub
              else params["embed"][batch["tokens_enc"]].astype(opts.dtype))
    enc_out = encode(cfg, params, enc_in, opts)
    h = decode_train(cfg, params, enc_out, batch["tokens"], opts)
    h = L.rmsnorm(h, params["final_norm"])
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    return _chunked_ce(h, head, batch["labels"])


# --------------------------------------------------------------------------
# decode (serve)
# --------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, batch: int, max_seq: int,
               enc_frames: int, opts: ModelOptions = DEFAULT_OPTIONS):
    hd, kh, n = cfg.head_dim, cfg.n_kv_heads, cfg.n_layers
    return {
        "pos": jnp.zeros((batch,), jnp.int32),
        "self": {
            "k": jnp.zeros((n, batch, max_seq, kh, hd), opts.dtype),
            "v": jnp.zeros((n, batch, max_seq, kh, hd), opts.dtype),
            "kpos": jnp.full((n, batch, max_seq), 2 ** 30, jnp.int32),
        },
        # precomputed cross-attention K/V over the encoder output
        "cross_k": jnp.zeros((n, batch, enc_frames, kh, hd), opts.dtype),
        "cross_v": jnp.zeros((n, batch, enc_frames, kh, hd), opts.dtype),
    }


def precompute_cross(cfg: ArchConfig, params, enc_out: jax.Array):
    """Fill cross_k/cross_v from an encoder pass (serve-time prefill)."""
    def per_layer(lp):
        k = jnp.einsum("bfd,de->bfe", enc_out, lp["cross"]["wk"])
        v = jnp.einsum("bfd,de->bfe", enc_out, lp["cross"]["wv"])
        if cfg.qkv_bias:
            k, v = k + lp["cross"]["bk"], v + lp["cross"]["bv"]
        b, f = k.shape[:2]
        return (k.reshape(b, f, cfg.n_kv_heads, cfg.head_dim),
                v.reshape(b, f, cfg.n_kv_heads, cfg.head_dim))
    return jax.vmap(per_layer)(params["dec_layers"])


def decode_step(cfg: ArchConfig, params, cache, batch,
                opts: ModelOptions = DEFAULT_OPTIONS):
    tok = batch["tokens"]
    x = params["embed"][tok].astype(opts.dtype)
    pos = cache["pos"]
    b = tok.shape[0]
    hd, kh = cfg.head_dim, cfg.n_kv_heads
    f = cache["cross_k"].shape[2]
    enc_pos = jnp.broadcast_to(jnp.arange(f), (b, f))

    def body(h, xs):
        lp, sk, sv, skp, ck, cv = xs
        # self-attention (ring buffer)
        p = {k: v for k, v in lp.items() if k not in ("ffn", "cross")}
        hn = L.rmsnorm(h, p["ln"])
        q = jnp.einsum("bsd,de->bse", hn, p["wq"])
        k = jnp.einsum("bsd,de->bse", hn, p["wk"])
        v = jnp.einsum("bsd,de->bse", hn, p["wv"])
        if cfg.qkv_bias:
            q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
        q = q.reshape(b, 1, cfg.n_heads, hd)
        k = k.reshape(b, 1, kh, hd)
        v = v.reshape(b, 1, kh, hd)
        qpos = pos[:, None]
        q = L.apply_rope(q, qpos, cfg.rope_theta)
        k = L.apply_rope(k, qpos, cfg.rope_theta)
        s = sk.shape[1]
        slot = (pos % s).astype(jnp.int32)
        bi = jnp.arange(b)
        sk = sk.at[bi, slot].set(k[:, 0])
        sv = sv.at[bi, slot].set(v[:, 0])
        skp = skp.at[bi, slot].set(pos)
        o = L.attention_decode(q, sk, sv, qpos, skp)
        h = h + jnp.einsum("bse,ed->bsd",
                           o.reshape(b, 1, cfg.n_heads * hd), p["wo"])

        # cross-attention over cached encoder K/V
        cp = lp["cross"]
        hn = L.rmsnorm(h, cp["ln"])
        q = jnp.einsum("bsd,de->bse", hn, cp["wq"])
        if cfg.qkv_bias:
            q = q + cp["bq"]
        q = q.reshape(b, 1, cfg.n_heads, hd)
        o = L.attention_decode(q, ck, cv, jnp.full((b, 1), 2 ** 29), enc_pos)
        h = h + jnp.einsum("bse,ed->bsd",
                           o.reshape(b, 1, cfg.n_heads * hd), cp["wo"])

        h, _ = _ffn_block(cfg, lp["ffn"], h, opts)
        return h, (sk, sv, skp)

    x, (nk, nv, nkp) = lax.scan(
        body, x,
        (params["dec_layers"], cache["self"]["k"], cache["self"]["v"],
         cache["self"]["kpos"], cache["cross_k"], cache["cross_v"]))

    new_cache = {**cache, "pos": pos + 1,
                 "self": {"k": nk, "v": nv, "kpos": nkp}}
    x = L.rmsnorm(x, params["final_norm"])
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    return jnp.einsum("bsd,dv->bsv", x, head)[:, 0], new_cache
