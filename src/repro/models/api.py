"""Unified model API: one entry point per architecture family.

``build_model(cfg, opts)`` returns a ``ModelAPI`` with functional
``init / forward / loss / init_cache / decode_step`` members, used by the
trainer, the server, the dry-run and the smoke tests alike.

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
model input of that (arch x shape) cell — weak-type-correct, shardable,
and allocation-free (the dry-run contract).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import encdec, lm
from repro.models.layers import ModelOptions, DEFAULT_OPTIONS

# VLM stub: number of precomputed patch-embedding positions
N_PATCHES = 1024


@dataclasses.dataclass(frozen=True)
class ModelAPI:
    cfg: ArchConfig
    opts: ModelOptions
    init: Callable[[jax.Array], Any]
    forward: Callable[..., jax.Array]
    loss: Callable[..., jax.Array]
    init_cache: Callable[..., Any]
    decode_step: Callable[..., Any]


def build_model(cfg: ArchConfig,
                opts: ModelOptions = DEFAULT_OPTIONS) -> ModelAPI:
    if cfg.enc_dec:
        def init_cache(batch: int, max_seq: int):
            return encdec.init_cache(cfg, batch, max_seq,
                                     enc_frames=max(max_seq // 2, 8),
                                     opts=opts)
        return ModelAPI(
            cfg=cfg, opts=opts,
            init=lambda key: encdec.init_params(cfg, key, opts),
            forward=lambda p, b: encdec.forward(cfg, p, b, opts),
            loss=lambda p, b: encdec.loss_fn(cfg, p, b, opts),
            init_cache=init_cache,
            decode_step=lambda p, c, b: encdec.decode_step(cfg, p, c, b, opts),
        )
    return ModelAPI(
        cfg=cfg, opts=opts,
        init=lambda key: lm.init_params(cfg, key, opts),
        forward=lambda p, b: lm.forward(cfg, p, b, opts),
        loss=lambda p, b: lm.loss_fn(cfg, p, b, opts),
        init_cache=lambda batch, max_seq: lm.init_cache(cfg, batch, max_seq,
                                                        opts),
        decode_step=lambda p, c, b: lm.decode_step(cfg, p, c, b, opts),
    )


# --------------------------------------------------------------------------
# input specs (dry-run stand-ins) and concrete batches (smoke tests)
# --------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ArchConfig, shape: ShapeConfig,
                opts: ModelOptions = DEFAULT_OPTIONS) -> Dict[str, Any]:
    """ShapeDtypeStructs for the *batch* argument of train/prefill steps,
    or the (cache, batch) pair for decode steps."""
    b, s = shape.global_batch, shape.seq_len
    if shape.kind in ("train", "prefill"):
        train = shape.kind == "train"
        if cfg.enc_dec:
            half = s // 2
            batch = {"tokens": _sds((b, half), jnp.int32)}
            if train:
                batch["labels"] = _sds((b, half), jnp.int32)
            if cfg.audio_stub:
                batch["frame_embeds"] = _sds((b, half, cfg.d_model),
                                             opts.dtype)
            else:
                batch["tokens_enc"] = _sds((b, half), jnp.int32)
            return batch
        if cfg.vision_stub:
            n_patches = min(N_PATCHES, s // 2)
            n_txt = s - n_patches
            batch = {"patch_embeds": _sds((b, n_patches, cfg.d_model),
                                          opts.dtype),
                     "tokens": _sds((b, n_txt), jnp.int32)}
            if train:
                batch["labels"] = _sds((b, n_txt), jnp.int32)
            return batch
        batch = {"tokens": _sds((b, s), jnp.int32)}
        if train:
            batch["labels"] = _sds((b, s), jnp.int32)
        return batch

    # decode: cache specs + one-token batch
    api = build_model(cfg, opts)
    cache = jax.eval_shape(lambda: api.init_cache(b, s))
    batch = {"tokens": _sds((b, 1), jnp.int32)}
    return {"cache": cache, "batch": batch}


def scenario_shape(scenario, global_batch: int, seq: int) -> ShapeConfig:
    """Bridge from the simulator's :class:`repro.core.scenario.Scenario`
    to the model-level ShapeConfig: the scenario kind picks the input
    contract (decode = one-token step over a KV cache of
    ``scenario.kv_len(seq)`` positions), so the simulated event graph
    and the executable model agree on shapes by construction."""
    kind = scenario.kind if scenario.kind in ("train", "prefill",
                                              "decode") else "train"
    s = scenario.kv_len(seq) if kind == "decode" else seq
    return ShapeConfig(name=f"{scenario.label()}_{s}", seq_len=s,
                       global_batch=global_batch, kind=kind)


def scenario_input_specs(cfg: ArchConfig, scenario, global_batch: int,
                         seq: int,
                         opts: ModelOptions = DEFAULT_OPTIONS
                         ) -> Dict[str, Any]:
    """``input_specs`` for a simulator scenario (see
    :func:`scenario_shape`)."""
    return input_specs(cfg, scenario_shape(scenario, global_batch, seq),
                       opts)


def make_batch(cfg: ArchConfig, shape: ShapeConfig, key: jax.Array,
               opts: ModelOptions = DEFAULT_OPTIONS) -> Dict[str, Any]:
    """Concrete random batch matching input_specs (smoke tests/examples)."""
    specs = input_specs(cfg, shape, opts)

    def realize(spec, k):
        if jnp.issubdtype(spec.dtype, jnp.integer):
            return jax.random.randint(k, spec.shape, 0,
                                      min(cfg.vocab, 32000), spec.dtype)
        return jax.random.normal(k, spec.shape, jnp.float32).astype(spec.dtype)

    leaves, treedef = jax.tree.flatten(specs)
    keys = jax.random.split(key, len(leaves))
    out = [realize(l, k) if isinstance(l, jax.ShapeDtypeStruct) else l
           for l, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, out)
