"""Mamba2 SSD: chunked scan vs naive recurrence oracle + properties."""
import pytest

hp = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.ssm import _segsum, ssd_chunked


def ssd_naive(x, dt, A, B_mat, C_mat):
    """O(L) sequential recurrence oracle: h ← h·exp(dtA) + dt·x⊗B."""
    b, l, h, p = x.shape
    n = B_mat.shape[-1]
    hstate = np.zeros((b, h, p, n), np.float64)
    ys = np.zeros((b, l, h, p), np.float64)
    xd = np.asarray(x, np.float64) * np.asarray(dt, np.float64)[..., None]
    dA = np.asarray(dt, np.float64) * np.asarray(A, np.float64)
    for t in range(l):
        decay = np.exp(dA[:, t])                       # (B,H)
        hstate = (hstate * decay[..., None, None]
                  + xd[:, t][..., None]
                  * np.asarray(B_mat, np.float64)[:, t, None, None, :])
        ys[:, t] = np.einsum("bhpn,bn->bhp", hstate,
                             np.asarray(C_mat, np.float64)[:, t])
    return ys, hstate


def _inputs(key, b, l, h, p, n):
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, l, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h), jnp.float32))
    A = -jnp.exp(jax.random.normal(ks[2], (h,), jnp.float32) * 0.5)
    B_mat = jax.random.normal(ks[3], (b, l, n), jnp.float32)
    C_mat = jax.random.normal(ks[4], (b, l, n), jnp.float32)
    return x, dt, A, B_mat, C_mat


def test_ssd_chunked_matches_recurrence():
    x, dt, A, B, C = _inputs(jax.random.PRNGKey(0), 2, 64, 3, 8, 16)
    y, hfin = ssd_chunked(x, dt, A, B, C, chunk=16)
    yref, href = ssd_naive(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y), yref, atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(hfin), href, atol=1e-3,
                               rtol=1e-3)


@hp.given(l=st.sampled_from([8, 24, 32, 56]),
          chunk=st.sampled_from([8, 16, 32]),
          seed=st.integers(0, 3))
@hp.settings(max_examples=12, deadline=None)
def test_ssd_chunk_size_invariance(l, chunk, seed):
    """Output must not depend on the chunk size (incl. ragged L)."""
    x, dt, A, B, C = _inputs(jax.random.PRNGKey(seed), 1, l, 2, 4, 8)
    y1, h1 = ssd_chunked(x, dt, A, B, C, chunk=chunk)
    y2, h2 = ssd_chunked(x, dt, A, B, C, chunk=l)     # single chunk
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-4,
                               rtol=2e-4)


def test_segsum_semantics():
    x = jnp.array([1.0, 2.0, 3.0])
    s = _segsum(x)
    assert float(s[0, 0]) == 0.0
    assert float(s[1, 0]) == 2.0          # sum of x[1..1]
    assert float(s[2, 0]) == 5.0          # x[1]+x[2]
    assert s[0, 1] == -jnp.inf


def test_ssd_state_decay_stability():
    """Strongly negative A ⇒ bounded outputs for long sequences."""
    x, dt, A, B, C = _inputs(jax.random.PRNGKey(2), 1, 512, 2, 4, 8)
    A = jnp.full_like(A, -2.0)
    y, _ = ssd_chunked(x, dt, A, B, C, chunk=64)
    assert bool(jnp.all(jnp.isfinite(y)))
    assert float(jnp.max(jnp.abs(y))) < 1e3
