"""Per-kernel shape/dtype sweeps: Pallas (interpret) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(42)

# Version gate, not a blanket xfail: these tests use jax>=0.6 APIs
# (jax.typeof, jax.lax.axis_size) and auto-activate — instead of
# silently xpassing — once the pinned jax is upgraded.
_JAX_VERSION = tuple(int(p) for p in jax.__version__.split(".")[:2])
needs_jax_0_6 = pytest.mark.skipif(
    _JAX_VERSION < (0, 6),
    reason=f"requires jax>=0.6 APIs (jax.typeof / jax.lax.axis_size); "
           f"running jax {jax.__version__} — runs again after upgrade")


def _qkv(b, s, h, kh, hd, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, s, h, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (b, s, kh, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, s, kh, hd), jnp.float32).astype(dtype)
    return q, k, v


def _ref(q, k, v, causal, window):
    b, s, h, hd = q.shape
    kh = k.shape[2]
    kk = jnp.repeat(k, h // kh, axis=2)
    vv = jnp.repeat(v, h // kh, axis=2)
    qb = q.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    kb = kk.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    vb = vv.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    o = ref.attention_ref(qb, kb, vb, causal, window)
    return o.reshape(b, h, s, hd).transpose(0, 2, 1, 3)


@pytest.mark.parametrize("b,s,h,kh,hd", [
    (1, 128, 4, 4, 64),
    (2, 256, 4, 2, 64),
    (1, 200, 8, 2, 32),      # ragged seq (padding path)
    (2, 64, 2, 1, 128),
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_shapes(b, s, h, kh, hd, causal):
    q, k, v = _qkv(b, s, h, kh, hd, jnp.float32)
    o = ops.flash_attention(q, k, v, causal=causal,
                            block_q=64, block_kv=96)
    oref = _ref(q, k, v, causal, None)
    np.testing.assert_allclose(np.asarray(o), np.asarray(oref),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("window", [16, 64, 1000])
def test_flash_attention_sliding_window(window):
    q, k, v = _qkv(1, 160, 4, 2, 32, jnp.float32)
    o = ops.flash_attention(q, k, v, causal=True, window=window,
                            block_q=64, block_kv=64)
    oref = _ref(q, k, v, True, window)
    np.testing.assert_allclose(np.asarray(o), np.asarray(oref),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype,atol", [(jnp.float32, 2e-5),
                                        (jnp.bfloat16, 2e-2)])
def test_flash_attention_dtypes(dtype, atol):
    q, k, v = _qkv(2, 128, 4, 2, 64, dtype)
    o = ops.flash_attention(q, k, v, causal=True)
    oref = _ref(q, k, v, True, None)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(oref, np.float32), atol=atol,
                               rtol=atol)


@pytest.mark.parametrize("shape", [(8, 128), (3, 100, 96), (2, 5, 7, 256),
                                   (1, 512)])
@pytest.mark.parametrize("dtype,atol", [(jnp.float32, 1e-5),
                                        (jnp.bfloat16, 2e-2)])
def test_rmsnorm_shapes_dtypes(shape, dtype, atol):
    x = jax.random.normal(KEY, shape, jnp.float32).astype(dtype)
    sc = jax.random.normal(jax.random.fold_in(KEY, 1), shape[-1:],
                           jnp.float32)
    o = ops.rmsnorm(x, sc)
    oref = ref.rmsnorm_ref(x, sc)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(oref, np.float32), atol=atol,
                               rtol=atol)


def test_model_layer_pallas_path_matches_naive():
    """attn_impl='pallas' end-to-end through the model layer."""
    from repro.models import layers as L
    q, k, v = _qkv(2, 128, 4, 2, 32, jnp.float32)
    qpos = jnp.broadcast_to(jnp.arange(128), (2, 128))
    o_naive = L.attention(q, k, v, qpos, qpos,
                          opts=L.ModelOptions(attn_impl="naive"))
    o_pallas = L.attention(q, k, v, qpos, qpos,
                           opts=L.ModelOptions(attn_impl="pallas"))
    np.testing.assert_allclose(np.asarray(o_pallas), np.asarray(o_naive),
                               atol=2e-5, rtol=2e-5)


@needs_jax_0_6
def test_combine_attention_partials_matches_full():
    """Online-softmax identity: attention over the full KV equals the
    exp-weighted combination of partials over disjoint KV shards — the
    math under ring attention (context parallelism)."""
    from repro.models import layers as L
    q, k, v = _qkv(2, 96, 4, 4, 32, jnp.float32)
    qpos = jnp.broadcast_to(jnp.arange(96), (2, 96))
    full = L.attention_naive(q, k, v, qpos, qpos, causal=True)
    parts = []
    for lo, hi in ((0, 32), (32, 64), (64, 96)):
        o, lse = L.attention_partial(q, k[:, lo:hi], v[:, lo:hi], qpos,
                                     qpos[:, lo:hi], causal=True,
                                     block_q=32, block_kv=32)
        parts.append((o, lse))
    combined = L.combine_attention_partials([p[0] for p in parts],
                                            [p[1] for p in parts])
    np.testing.assert_allclose(np.asarray(combined), np.asarray(full),
                               atol=2e-5, rtol=2e-5)


@needs_jax_0_6
def test_ring_attention_single_ring():
    """ring_attention on a 1-element ring == plain flash attention."""
    import jax
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.models import layers as L
    q, k, v = _qkv(1, 64, 4, 2, 32, jnp.float32)
    qpos = jnp.broadcast_to(jnp.arange(64), (1, 64))
    mesh = jax.make_mesh((1,), ("cp",))
    # realistic usage: sequence sharded over the ring axis
    f = shard_map(
        lambda q, k, v, qp: L.ring_attention(q, k, v, qp, qp, "cp",
                                             block_q=32, block_kv=32),
        mesh=mesh,
        in_specs=(P(None, "cp"), P(None, "cp"), P(None, "cp"),
                  P(None, "cp")),
        out_specs=P(None, "cp"))
    out = f(q, k, v, qpos)
    ref = L.attention_flash_jnp(q, k, v, qpos, qpos, block_q=32,
                                block_kv=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)
