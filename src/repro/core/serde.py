"""Shared dataclass↔dict round-trip helpers (validation reports,
goldens, search artifacts)."""
from __future__ import annotations

import dataclasses


def dataclass_from_dict(cls, d: dict):
    """Construct ``cls`` from a dict, ignoring unknown keys — the one
    place that defines how report dicts rehydrate, so schema-migration
    behavior changes in exactly one spot."""
    fields = {f.name for f in dataclasses.fields(cls)}
    return cls(**{k: v for k, v in d.items() if k in fields})
