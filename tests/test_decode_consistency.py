"""Decode-vs-forward consistency: running the decode path token-by-token
must reproduce the teacher-forced forward logits — validates KV caches,
SSM recurrent states, ring buffers and rope positions across families.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, smoke_config
from repro.models.api import build_model
from repro.models.layers import ModelOptions

OPTS = ModelOptions(dtype=jnp.float32, remat=False, attn_impl="naive")

# one representative per family (full 10-arch coverage in smoke tests)
_MOE_DECODE_XFAIL = pytest.mark.xfail(
    reason="seed-known: MoE decode path diverges from batched forward",
    strict=False)
FAMILIES = ["qwen2_1_5b",        # dense GQA
            "h2o_danube_1_8b",   # SWA
            "mamba2_2_7b",       # SSM
            pytest.param("qwen3_moe_30b_a3b",   # MoE
                         marks=_MOE_DECODE_XFAIL),
            pytest.param("jamba_v0_1_52b",      # hybrid
                         marks=_MOE_DECODE_XFAIL),
            "whisper_tiny"]      # enc-dec


@pytest.mark.parametrize("arch", FAMILIES)
def test_decode_matches_forward(arch):
    cfg = smoke_config(get_config(arch))
    api = build_model(cfg, OPTS)
    key = jax.random.PRNGKey(1)
    params = api.init(key)
    b, s = 2, 16
    toks = jax.random.randint(jax.random.fold_in(key, 1), (b, s), 1,
                              cfg.vocab, jnp.int32)

    if cfg.enc_dec:
        frames = jax.random.normal(jax.random.fold_in(key, 2),
                                   (b, 8, cfg.d_model), jnp.float32)
        batch = {"tokens": toks, "frame_embeds": frames}
        full = api.forward(params, batch)           # (b, s, V)
        from repro.models import encdec
        enc_out = encdec.encode(cfg, params, frames, OPTS)
        ck, cv = encdec.precompute_cross(cfg, params, enc_out)
        cache = {**api.init_cache(b, s), "cross_k": ck, "cross_v": cv}
    else:
        batch = {"tokens": toks}
        full = api.forward(params, batch)
        cache = api.init_cache(b, s)

    step = jax.jit(api.decode_step)
    for t in range(s):
        logits, cache = step(params, cache, {"tokens": toks[:, t:t + 1]})
        ref = full[:, t]
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(ref), atol=2e-3, rtol=2e-3,
            err_msg=f"{arch}: mismatch at position {t}")


def test_swa_ring_buffer_evicts_correctly():
    """With window w, decode at position >= w must match forward —
    exercising slot eviction in the rolling cache."""
    cfg = smoke_config(get_config("h2o_danube_1_8b"))
    assert cfg.sliding_window == 32
    api = build_model(cfg, OPTS)
    key = jax.random.PRNGKey(3)
    params = api.init(key)
    b, s = 1, 48                      # > window 32
    toks = jax.random.randint(key, (b, s), 1, cfg.vocab, jnp.int32)
    full = api.forward(params, {"tokens": toks})
    cache = api.init_cache(b, s)
    step = jax.jit(api.decode_step)
    for t in range(s):
        logits, cache = step(params, cache, {"tokens": toks[:, t:t + 1]})
    np.testing.assert_allclose(np.asarray(logits),
                               np.asarray(full[:, -1]), atol=2e-3,
                               rtol=2e-3)
