"""DistSim: event-based performance model of hybrid distributed training.

The paper's primary contribution: events (dedup of identical work),
profiling providers, hierarchical MP→PP→DP timeline construction, the
replay oracle, and the strategy-search use-case.

Public API:
    from repro.core import DistSim, SimBatch, Strategy
"""
from repro.core.events import (Strategy, Event, ComposedEvent,
                               stage_signature)
from repro.core.engine import EngineBuild, EventFlowEngine
from repro.core.simulator import DistSim, SimBatch, SimResult
from repro.core.megabatch import (MegaBatch, MegaPredict,
                                  megabatch_predict)
from repro.core.perturb import (DegradedRun, Fault, Perturbation,
                                Straggler, perturbation_from_dict,
                                simulate_degraded)
from repro.core.search import grid_search, SearchEntry
from repro.core.costmodel import (ClusterSpec, CLUSTERS, V5E_POD,
                                  A40_CLUSTER, collective_time,
                                  get_cluster, p2p_time, ring_hops,
                                  ring_volume_factor)
from repro.core.profiler import (AnalyticalProvider, MeasuredProvider,
                                 Provider, ProviderStats, profiling_cost)
from repro.core.timeline import (Timeline, Activity, LazyTimeline,
                                 TimelineBatch, batch_time_error,
                                 activity_error, per_stage_error)

__all__ = [
    "DistSim", "SimBatch", "SimResult", "Strategy", "Event",
    "ComposedEvent", "stage_signature", "EngineBuild", "EventFlowEngine",
    "MegaBatch", "MegaPredict", "megabatch_predict",
    "DegradedRun", "Fault", "Perturbation", "Straggler",
    "perturbation_from_dict", "simulate_degraded",
    "grid_search", "SearchEntry", "ClusterSpec", "CLUSTERS", "V5E_POD",
    "A40_CLUSTER", "get_cluster", "AnalyticalProvider", "MeasuredProvider",
    "Provider", "ProviderStats", "profiling_cost",
    "Timeline", "Activity", "LazyTimeline", "TimelineBatch",
    "batch_time_error", "activity_error",
    "per_stage_error", "collective_time", "p2p_time",
    "ring_hops", "ring_volume_factor",
]
