"""DistSim top-level API (paper Fig. 6).

    sim = DistSim(cfg, strategy, global_batch=16, seq=512)
    result = sim.predict()          # deduped-event timeline (the model)
    actual = sim.replay(seed=0)     # discrete-event oracle ("actual run")

``predict`` uses each unique event's profiled mean once — the paper's
construction. ``replay`` executes every per-device event instance with
profiling jitter, straggler and clock effects — our stand-in for the real
16-GPU cluster (see DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.configs.base import ArchConfig
from repro.core.costmodel import V5E_POD
from repro.core.engine import EventFlowEngine
from repro.core.events import (Stage, Strategy, build_stage_events,
                               stage_signature, unique_events)
from repro.core.hierarchy import build_positions
from repro.core.profiler import (AnalyticalProvider, Provider,
                                 profile_events, profiling_cost)
from repro.core.timeline import Timeline, TimelineBatch


@dataclasses.dataclass
class SimResult:
    timeline: Timeline
    batch_time: float
    throughput_iters: float
    throughput_tokens: float
    utilization: Dict[int, float]
    bubble_fraction: float


class DistSim:
    def __init__(self, cfg: ArchConfig, strategy: Strategy,
                 global_batch: int, seq: int,
                 provider: Optional[Provider] = None):
        self.cfg = cfg
        self.strategy = strategy
        self.global_batch = global_batch
        self.seq = seq
        self.provider = provider or AnalyticalProvider(V5E_POD)
        self._default_engine: Optional[EventFlowEngine] = None
        self._engine: Optional[EventFlowEngine] = None
        self._engine_key = None
        if global_batch % (strategy.dp * strategy.microbatches):
            raise ValueError(
                f"global_batch {global_batch} not divisible by "
                f"dp*microbatches = {strategy.dp * strategy.microbatches}")

    # ---- the performance model ----
    def predict(self, positions: Optional[List[Stage]] = None) -> SimResult:
        return self._result(self.engine(positions).run())

    # ---- the "actual run" oracle ----
    def replay(self, seed: int = 0, jitter_sigma: float = 0.025,
               straggler_sigma: float = 0.0,
               clock_sigma: float = 0.0,
               positions: Optional[List[Stage]] = None) -> SimResult:
        tl = self.engine(positions).run(jitter_sigma=jitter_sigma,
                                        straggler_sigma=straggler_sigma,
                                        clock_sigma=clock_sigma, seed=seed)
        return self._result(tl)

    # ---- batched array-native paths (repro.validate hot loop) ----
    def predict_batched(self, positions: Optional[List[Stage]] = None
                        ) -> TimelineBatch:
        """The zero-noise prediction as a single-lane TimelineBatch —
        same numbers as ``predict()``, but with the per-task arrays the
        array-native validation metrics consume directly."""
        return self.engine(positions).run_batched(None)

    def replay_batched(self, seeds, jitter_sigma: float = 0.025,
                       straggler_sigma: float = 0.0,
                       clock_sigma: float = 0.0,
                       positions: Optional[List[Stage]] = None
                       ) -> TimelineBatch:
        """All seeds' replay oracles in one vectorized pass —
        bit-identical per seed to sequential ``replay(seed=s)`` calls
        (asserted in ``tests/test_engine.py``), without materializing a
        single ``Activity``."""
        return self.engine(positions).run_batched(
            seeds, jitter_sigma=jitter_sigma,
            straggler_sigma=straggler_sigma, clock_sigma=clock_sigma)

    # ---- conformance hook (repro.validate) ----
    def predict_and_replay(self, seeds=(0,), jitter_sigma: float = 0.025,
                           straggler_sigma: float = 0.0,
                           clock_sigma: float = 0.0, batched: bool = True):
        """One prediction plus a replay per seed, all sharing a single
        event-flow engine (one positions build, one event profile) —
        the per-cell unit of the accuracy sweep.

        With ``batched=True`` (the default) the replays come from one
        ``run_batched`` pass and the returned ``SimResult`` timelines
        are lazy per-lane views; ``batched=False`` keeps the sequential
        one-``run()``-per-seed oracle (the differential baseline).
        Returns ``(pred, [replay_0, ...])``."""
        engine = self.engine()
        pred = self._result(engine.run())
        if batched:
            batch = engine.run_batched(seeds, jitter_sigma=jitter_sigma,
                                       straggler_sigma=straggler_sigma,
                                       clock_sigma=clock_sigma)
            replays = [self._result(batch.timeline(i))
                       for i in range(len(batch))]
        else:
            replays = [self._result(engine.run(
                jitter_sigma=jitter_sigma,
                straggler_sigma=straggler_sigma,
                clock_sigma=clock_sigma, seed=s)) for s in seeds]
        return pred, replays

    # ---- search-engine hooks ----
    def microbatch(self) -> int:
        return max(1, self.global_batch
                   // (self.strategy.dp * self.strategy.microbatches))

    def positions(self) -> List[Stage]:
        """Pipeline positions (pp*vpp stages) with composed fwd/bwd
        events — precompute once, pass to predict()/replay() and the
        search pruner so candidates don't rebuild the model graph."""
        return build_positions(self.cfg, self.strategy, self.microbatch(),
                               self.seq, self.provider.cluster)

    def engine(self, positions: Optional[List[Stage]] = None
               ) -> EventFlowEngine:
        """Event-flow engine for this sim. Reused across predict/replay
        calls (one slot for the default positions build, one keyed on
        the caller's positions) so the per-strategy schedule +
        event-mean precomputation runs once per positions set.

        Explicit positions are keyed on STRUCTURAL content
        (:func:`repro.core.events.stage_signature`), not list identity:
        an equal-content list reuses the cached engine, and a
        mutated-then-reused list rebuilds instead of silently returning
        stale times. Either slot also rebuilds when the provider's
        event cache was cleared since the engine baked in its means."""
        if positions is None:
            if (self._default_engine is None
                    or self._stale(self._default_engine)):
                self._default_engine = EventFlowEngine(
                    self.positions(), self.strategy, self.provider)
            return self._default_engine
        key = stage_signature(positions)
        if (self._engine is None or self._engine_key != key
                or self._stale(self._engine)):
            self._engine = EventFlowEngine(positions, self.strategy,
                                           self.provider)
            self._engine_key = key
        return self._engine

    def use_engine(self, engine: EventFlowEngine) -> None:
        """Adopt a prebuilt default engine (the validate sweep's
        :class:`~repro.validate.build_cache.BuildCache` hands sims
        cached engines so per-cell predict/replay skips the build)."""
        if engine.provider is not self.provider:
            raise ValueError("engine was built against a different "
                             "provider than this sim's")
        self._default_engine = engine

    def _stale(self, engine: EventFlowEngine) -> bool:
        return engine.cache_version != self.provider.cache_version

    def _result(self, tl: Timeline) -> SimResult:
        bt = tl.batch_time
        util = tl.utilization()
        return SimResult(
            timeline=tl,
            batch_time=bt,
            throughput_iters=1.0 / bt if bt else 0.0,
            throughput_tokens=self.global_batch * self.seq / bt if bt else 0,
            utilization=util,
            bubble_fraction=tl.bubble_fraction(util),
        )

    # ---- Table 3 accounting ----
    def profiling_report(self) -> Dict[str, float]:
        micro = self.microbatch()     # shared floor — paths can't drift
        stages = build_stage_events(self.cfg, self.strategy, micro, self.seq,
                                    self.provider.cluster.devices_per_island)
        counts = unique_events(stages, self.strategy,
                               self.provider.cluster.devices_per_island)
        profile = profile_events(counts.keys(), self.provider)
        return profiling_cost(counts, profile)
