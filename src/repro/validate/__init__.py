"""repro.validate — paper-fidelity accuracy sweep (predict vs replay).

The regression backbone for DistSim's headline claim (<4% batch-time
error, <5% per-device activity error, paper §5):

    from repro.validate import run_sweep, smoke_matrix
    from repro.validate.report import dump, format_validation_report

    result = run_sweep(smoke_matrix(), seeds=(0, 1, 2))
    print(format_validation_report(result))
    assert result.passed

``benchmarks/bench_validate.py --smoke`` wraps this for CI;
``tests/test_validation.py`` is the tier-1 gate with goldens under
``tests/goldens/``.
"""
from repro.validate.build_cache import BuildCache, BuildCacheStats
from repro.validate.degraded import (DegradedCell, DegradedCellResult,
                                     DegradedReport, degraded_matrix,
                                     format_degraded_report, run_degraded,
                                     run_degraded_cell,
                                     structural_violations)
from repro.validate.metrics import (CellMetrics, aggregate, compare_batch,
                                    compare_timelines)
from repro.validate.report import (dump, dumps, format_validation_report,
                                   load, load_path, save)
from repro.validate.sweep import (CellResult, SweepResult, Thresholds,
                                  ValidationCell, full_matrix, run_cell,
                                  run_sweep, serving_matrix, smoke_matrix)

__all__ = [
    "BuildCache", "BuildCacheStats", "DegradedCell",
    "DegradedCellResult", "DegradedReport", "degraded_matrix",
    "format_degraded_report", "run_degraded", "run_degraded_cell",
    "structural_violations", "CellMetrics", "aggregate",
    "compare_batch", "compare_timelines", "dump", "dumps",
    "format_validation_report", "load", "load_path", "save",
    "CellResult", "SweepResult", "Thresholds", "ValidationCell",
    "full_matrix", "run_cell", "run_sweep", "serving_matrix",
    "smoke_matrix",
]
