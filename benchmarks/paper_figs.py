"""One benchmark per paper table/figure (DistSim, CF'23).

Each function returns a list of CSV rows ``(name, us_per_call, derived)``
where ``us_per_call`` is the simulated batch time (µs) and ``derived``
carries the figure's headline metric (error %, speedup, ratio).

The "actual" side of every comparison is the discrete-event replay
oracle with profiling jitter/straggler/clock noise (DESIGN.md §2 —
we own no 16-GPU A40 cluster; the oracle reproduces the paper's error
sources). Cluster constants follow the paper's testbed shape
(A40_CLUSTER).
"""
from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np

from repro.configs.base import get_config
from repro.core import (A40_CLUSTER, AnalyticalProvider, DistSim, Strategy,
                        activity_error, batch_time_error,
                        per_stage_error)

Row = Tuple[str, float, str]

PROVIDER = AnalyticalProvider(A40_CLUSTER)

# strategies used across Fig. 3/8/9 ("xM xP xD", microbatches)
_STRATS = [
    ("1m1p4d", Strategy(mp=1, pp=1, dp=4, microbatches=1)),
    ("1m2p2d", Strategy(mp=1, pp=2, dp=2, microbatches=4)),
    ("2m2p1d", Strategy(mp=2, pp=2, dp=1, microbatches=4)),
    ("1m2p4d", Strategy(mp=1, pp=2, dp=4, microbatches=4)),
    ("2m2p2d", Strategy(mp=2, pp=2, dp=2, microbatches=4)),
    ("2m2p4d", Strategy(mp=2, pp=2, dp=4, microbatches=4)),
    ("2m4p2d", Strategy(mp=2, pp=4, dp=2, microbatches=8)),
]
_MODELS = ["bert_large", "gpt2_345m", "t5_large"]


def fig8_batch_time() -> List[Row]:
    """§5.2 / Fig. 8: iteration-time prediction error (<4% claimed)."""
    rows = []
    worst = 0.0
    for model in _MODELS:
        cfg = get_config(model)
        for label, strat in _STRATS:
            sim = DistSim(cfg, strat, global_batch=16, seq=512,
                          provider=PROVIDER)
            pred = sim.simulate().result()
            batch = sim.simulate(seeds=range(5), jitter_sigma=0.025)
            errs = [batch_time_error(pred.timeline, batch.timeline(i))
                    for i in range(len(batch))]
            err = float(np.mean(errs))
            worst = max(worst, err)
            rows.append((f"fig8/{model}/{label}",
                         pred.batch_time * 1e6, f"err={err*100:.2f}%"))
    rows.append(("fig8/max_error", 0.0,
                 f"max={worst*100:.2f}% (paper: <4%)"))
    return rows


def fig9_device_activity() -> List[Row]:
    """§5.3 / Fig. 9: per-GPU activity error (<5% claimed)."""
    rows = []
    worst = 0.0
    for model in _MODELS:
        cfg = get_config(model)
        for label, strat in _STRATS[:5]:
            sim = DistSim(cfg, strat, 16, 512, PROVIDER)
            pred = sim.simulate().result()
            act = sim.simulate(seeds=1, jitter_sigma=0.025,
                               clock_sigma=2e-5).result()
            errs = activity_error(pred.timeline, act.timeline)
            e = max(errs.values())
            worst = max(worst, e)
            rows.append((f"fig9/{model}/{label}",
                         pred.batch_time * 1e6,
                         f"max_dev_err={e*100:.2f}%"))
    rows.append(("fig9/max_error", 0.0,
                 f"max={worst*100:.2f}% (paper: <5%)"))
    return rows


def fig10_per_stage() -> List[Row]:
    """§5.4 / Fig. 10: per-stage timestamp error, 2M4P(1D), micro 4.

    Paper: largest per-stage median error 1.71%; error grows with
    pipeline depth (stage index)."""
    cfg = get_config("bert_large")
    strat = Strategy(mp=2, pp=4, dp=1, microbatches=4)
    sim = DistSim(cfg, strat, 16, 512, PROVIDER)
    pred = sim.simulate().result()
    per_key = {}
    batch = sim.simulate(seeds=range(20), jitter_sigma=0.025)
    for i in range(len(batch)):
        for k, v in per_stage_error(pred.timeline,
                                    batch.timeline(i)).items():
            per_key.setdefault(k, []).append(v)
    medians = {k: float(np.median(v)) for k, v in per_key.items()}
    worst = max(medians.values())
    # per-stage mean error (F only) to show depth growth
    rows = []
    by_stage = {}
    for (dev, name), m in medians.items():
        if name.startswith("F"):
            st = int(name.split(":")[1][1:])
            by_stage.setdefault(st, []).append(m)
    for st in sorted(by_stage):
        rows.append((f"fig10/stage{st}", 0.0,
                     f"median_err={np.mean(by_stage[st])*100:.3f}%"))
    grows = (np.mean(by_stage[max(by_stage)])
             >= np.mean(by_stage[min(by_stage)]))
    rows.append(("fig10/max_median_error", pred.batch_time * 1e6,
                 f"max={worst*100:.2f}% (paper: 1.71%); "
                 f"grows_with_depth={grows}"))
    return rows


# Megatron-LM SC'21 Fig. 17 (145.6B, 8-way TP x 16-way PP, 128 GPUs):
# achieved aggregate throughput rises with global batch size thanks to
# smaller relative pipeline bubble. Digitized (batch, petaFLOP/s):
_MEGATRON_145B = [(12, 40.0), (24, 61.0), (36, 72.0), (48, 79.0),
                  (60, 84.0)]


def fig11_large_scale() -> List[Row]:
    """§5.5 / Fig. 11: 145B GPT, "8M16P1D" on 128 GPUs — normalized
    throughput trend vs Megatron-LM's published curve."""
    cfg = get_config("gpt_145b")
    ours = []
    for gb, _ in _MEGATRON_145B:
        strat = Strategy(mp=8, pp=16, dp=1, microbatches=gb)
        sim = DistSim(cfg, strat, global_batch=gb, seq=2048,
                      provider=PROVIDER)
        res = sim.simulate().result()
        ours.append(gb / res.batch_time)          # samples/s
    # both curves normalized to the smallest batch: samples/s ratio vs
    # achieved-FLOP/s ratio (same model ⇒ directly comparable trends)
    ours_norm = [o / ours[0] for o in ours]
    mega_norm = [t / _MEGATRON_145B[0][1] for _, t in _MEGATRON_145B]
    rows = []
    errs = []
    for (gb, _), o, m in zip(_MEGATRON_145B, ours_norm, mega_norm):
        errs.append(abs(o - m) / m)
        rows.append((f"fig11/batch{gb}", 0.0,
                     f"ours={o:.3f} megatron={m:.3f}"))
    rows.append(("fig11/trend_mean_dev", 0.0,
                 f"mean_dev={np.mean(errs)*100:.1f}% "
                 f"(trend similarity vs published curve)"))
    return rows


def fig12_table2_search() -> List[Row]:
    """§6 / Fig. 12 + Table 2: BERT-exLarge strategy search, 16 GPUs,
    global batch 16. Paper: best 2.94 it/s, worst 0.398, speedup 7.379x;
    actual measurement confirms the ranking."""
    cfg = get_config("bert_exlarge")
    from repro.search import ProfileCache, SearchEngine
    t0 = time.perf_counter()
    entries = SearchEngine(
        cfg, cache=ProfileCache.from_provider(PROVIDER),
        prune=False, check_memory=False).search(16, 16, 512).entries
    search_time = time.perf_counter() - t0
    feasible = [e for e in entries if e.feasible]
    best, second, worst = feasible[0], feasible[1], feasible[-1]
    # "actual" verification via replay oracle
    act_best = DistSim(cfg, best.strategy, 16, 512, PROVIDER
                       ).simulate(seeds=0).result()
    act_worst = DistSim(cfg, worst.strategy, 16, 512, PROVIDER
                        ).simulate(seeds=0).result()
    rows = [
        ("fig12/best", best.batch_time * 1e6,
         f"{best.strategy.label()}@m{best.strategy.microbatches}"
         f"={best.iters_per_s:.2f}it/s"),
        ("fig12/second", second.batch_time * 1e6,
         f"{second.strategy.label()}={second.iters_per_s:.2f}it/s"),
        ("fig12/worst", worst.batch_time * 1e6,
         f"{worst.strategy.label()}={worst.iters_per_s:.3f}it/s"),
        ("table2/speedup", search_time * 1e6,
         f"speedup={worst.batch_time/best.batch_time:.2f}x "
         f"(paper: 7.379x)"),
        ("table2/actual_confirms", 0.0,
         f"replay best {1/act_best.batch_time:.2f} > "
         f"worst {1/act_worst.batch_time:.3f} it/s = "
         f"{act_best.batch_time < act_worst.batch_time}"),
    ]
    return rows


def table3_profiling_cost() -> List[Row]:
    """§6 / Table 3: profiling cost vs direct running (paper: 0.1296x)."""
    cfg = get_config("bert_exlarge")
    rows = []
    scales = []
    for label, strat in [("2m1p8d", Strategy(mp=2, dp=8, microbatches=1)),
                         ("2m4p2d", Strategy(mp=2, pp=4, dp=2,
                                             microbatches=8)),
                         ("1m8p2d", Strategy(pp=8, dp=2,
                                             microbatches=8))]:
        sim = DistSim(cfg, strat, 16, 512, PROVIDER)
        t0 = time.perf_counter()
        rep = sim.profiling_report()
        sim_time = time.perf_counter() - t0
        scales.append(rep["relative_scale"])
        rows.append((f"table3/{label}", sim_time * 1e6,
                     f"unique={rep['unique_events']} "
                     f"instances={rep['total_instances']} "
                     f"scale={rep['relative_scale']:.4f}"))
    rows.append(("table3/mean_scale", 0.0,
                 f"mean={np.mean(scales):.4f} (paper: 0.1296)"))
    return rows


def tab_allreduce_extrapolation() -> List[Row]:
    """§4.2: ≤8-way profile → N-way extrapolation error (<2% claimed)."""
    from repro.core.costmodel import collective_time
    from repro.core.events import Event
    rows = []
    worst = 0.0
    for n in (16, 32, 64, 128, 256):
        for nbytes in (1e6, 1e8):
            e = Event(kind="collective", name="x", coll_op="all_reduce",
                      nbytes=nbytes, n_dev=n, scope="inter")
            t_x = PROVIDER.time(e)
            t_d = collective_time("all_reduce", nbytes, n, A40_CLUSTER,
                                  "inter")
            err = abs(t_x - t_d) / t_d
            worst = max(worst, err)
            rows.append((f"allreduce_extrap/n{n}/{int(nbytes)}B",
                         t_d * 1e6, f"err={err*100:.3f}%"))
    rows.append(("allreduce_extrap/max", 0.0,
                 f"max={worst*100:.3f}% (paper: <2%)"))
    return rows


ALL = [fig8_batch_time, fig9_device_activity, fig10_per_stage,
       fig11_large_scale, fig12_table2_search, table3_profiling_cost,
       tab_allreduce_extrapolation]


def straggler_whatif() -> List[Row]:
    """Beyond-paper use-case: DistSim as a straggler what-if tool.

    Injects one slow DP replica (1.3x step time) into the replay oracle
    and compares three policies: do nothing (bulk-synchronous stall),
    drop the replica (elastic re-plan to dp-1), or re-balance
    microbatches. The timeline quantifies each — the decision a
    1000-node scheduler has to make on every detected straggler."""
    import numpy as np
    cfg = get_config("bert_large")
    strat = Strategy(mp=1, pp=2, dp=4, microbatches=4)
    sim = DistSim(cfg, strat, 16, 512, PROVIDER)
    healthy = sim.simulate().batch_time

    # policy 0: tolerate the straggler (sync stall at the gradient AR)
    slow = sim.simulate(seeds=7, jitter_sigma=0.0, straggler_sigma=0.0,
                        clock_sigma=0.0)
    from repro.core.hierarchy import construct_timeline
    tl = construct_timeline(cfg, strat, 16, 512, sim.provider,
                            straggler_sigma=0.3, seed=7)
    stalled = tl.batch_time

    # policy 1: drop to dp=3 ⇒ invalid (16 % 3); re-plan to dp=2
    strat2 = Strategy(mp=1, pp=2, dp=2, microbatches=4)
    dropped = DistSim(cfg, strat2, 16, 512,
                      PROVIDER).simulate().batch_time

    rows = [
        ("straggler/healthy", healthy * 1e6, "baseline"),
        ("straggler/tolerate", stalled * 1e6,
         f"+{(stalled/healthy-1)*100:.0f}% (sync stall)"),
        ("straggler/replan_dp2", dropped * 1e6,
         f"+{(dropped/healthy-1)*100:.0f}% (fewer replicas)"),
        ("straggler/decision", 0.0,
         "tolerate" if stalled < dropped else "replan"),
    ]
    return rows


def fig2_schedule_comparison() -> List[Row]:
    """Paper Fig. 2: GPipe vs Dapple bubble structure (+ our
    interleaved and PipeDream-async extensions)."""
    cfg = get_config("bert_exlarge")
    rows = []
    for name in ("gpipe", "1f1b", "interleaved", "pipedream"):
        strat = Strategy(mp=1, pp=4, dp=1, microbatches=8,
                         schedule=name, vpp=2 if name == "interleaved"
                         else 1)
        res = DistSim(cfg, strat, 8, 512,
                      PROVIDER).simulate().result()
        rows.append((f"fig2/{name}", res.batch_time * 1e6,
                     f"bubble={res.bubble_fraction*100:.1f}%"))
    return rows


ALL = ALL + [straggler_whatif, fig2_schedule_comparison]


def grad_compression_whatif() -> List[Row]:
    """Beyond-paper: DistSim what-if for int8 gradient compression on a
    DP-heavy strategy (the multi-pod DCN regime — weights sync crosses
    the slow inter-island link). Numerics of the compressor are verified
    in tests/test_train_substrate.py; here DistSim quantifies the
    payoff before anyone re-deploys the cluster."""
    cfg = get_config("bert_exlarge")
    rows = []
    for label, ratio in (("fp16", 1.0), ("int8", 0.5), ("int8+ef", 0.25)):
        strat = Strategy(mp=1, pp=1, dp=16, microbatches=1,
                         grad_compress=ratio)
        res = DistSim(cfg, strat, 16, 512,
                      PROVIDER).simulate().result()
        rows.append((f"grad_compress/{label}", res.batch_time * 1e6,
                     f"{res.throughput_iters:.2f} it/s"))
    base = float(rows[0][1])
    rows.append(("grad_compress/speedup", 0.0,
                 f"{base/float(rows[-1][1]):.2f}x on DP-bound strategy"))
    return rows


ALL = ALL + [grad_compression_whatif]
