"""Pipeline schedules (paper §2.1.3, §4.3): GPipe, Dapple/1F1B, interleaved.

A schedule is, per pipeline stage, an ordered list of ``Task``s. The
hierarchical modeler turns these into timed activities; the same lists
drive the replay oracle. ``interleaved`` (Megatron interleaved-1F1B,
beyond the paper) assigns ``vpp`` virtual stage chunks per device.
"""
from __future__ import annotations

import dataclasses
from typing import List


@dataclasses.dataclass(frozen=True)
class Task:
    phase: str          # "F" | "B"
    micro: int
    chunk: int = 0      # virtual stage chunk (interleaved only)


def gpipe(pp: int, m: int) -> List[List[Task]]:
    """All forwards, then all backwards in reverse micro order."""
    return [[Task("F", i) for i in range(m)]
            + [Task("B", i) for i in reversed(range(m))]
            for _ in range(pp)]


def one_f_one_b(pp: int, m: int) -> List[List[Task]]:
    """Dapple / PipeDream-flush: warmup F, steady 1F1B, cooldown B."""
    out = []
    for d in range(pp):
        w = min(m, pp - 1 - d)
        tasks: List[Task] = [Task("F", i) for i in range(w)]
        nf, nb = w, 0
        for _ in range(m - w):
            tasks.append(Task("F", nf)); nf += 1
            tasks.append(Task("B", nb)); nb += 1
        tasks.extend(Task("B", i) for i in range(nb, m))
        out.append(tasks)
    return out


def interleaved(pp: int, m: int, vpp: int) -> List[List[Task]]:
    """Interleaved 1F1B with vpp virtual chunks per device (simplified
    Megatron schedule: warmup proportional to vpp, round-robin chunks)."""
    if vpp == 1:
        return one_f_one_b(pp, m)
    out = []
    total_f = m * vpp
    for d in range(pp):
        # Megatron warmup count for interleaved 1F1B
        w = min(total_f, (pp - d - 1) * 2 + (vpp - 1) * pp)
        # forward issue order: groups of pp microbatches, chunk-major
        fseq = []
        for base in range(0, m, pp):
            for c in range(vpp):
                for i in range(base, min(base + pp, m)):
                    fseq.append((c, i))
        # backward order: same micro groups, chunks in REVERSE (deepest
        # pipeline position drains first)
        bseq = []
        for base in range(0, m, pp):
            for c in reversed(range(vpp)):
                for i in range(base, min(base + pp, m)):
                    bseq.append((c, i))
        tasks: List[Task] = [Task("F", i, c) for (c, i) in fseq[:w]]
        nf, nb = w, 0
        while nf < total_f:
            c, i = fseq[nf]; tasks.append(Task("F", i, c)); nf += 1
            c, i = bseq[nb]; tasks.append(Task("B", i, c)); nb += 1
        while nb < total_f:
            c, i = bseq[nb]; tasks.append(Task("B", i, c)); nb += 1
        out.append(tasks)
    return out


def forward_only(pp: int, m: int) -> List[List[Task]]:
    """Serving schedule: every stage runs the m pipelined work units
    (prefill requests / decode steps) forward-only, in order. The
    scenario's event graph carries the inter-unit dependencies (p2p
    activations; decode's token feedback + arrival floors)."""
    return [[Task("F", i) for i in range(m)] for _ in range(pp)]


def build_schedule(name: str, pp: int, m: int, vpp: int = 1
                   ) -> List[List[Task]]:
    if name == "gpipe":
        return gpipe(pp, m)
    if name in ("1f1b", "dapple"):
        return one_f_one_b(pp, m)
    if name == "interleaved":
        return interleaved(pp, m, vpp)
    if name == "pipedream":
        return pipedream(pp, m)
    raise ValueError(f"unknown schedule {name!r}")


def pipedream(pp: int, m: int) -> List[List[Task]]:
    """Asynchronous pipeline (PipeDream) schedule — paper §7 discussion:
    "the schedule in pipeline parallelism modeling can still be
    established only without a global synchronize event".

    Steady-state 1F1B without the flush: after warmup every stage
    alternates F/B indefinitely; we model one epoch of m microbatches.
    The DP gradient sync event is omitted by the modeler when
    ``Strategy.schedule == "pipedream"`` (weights update asynchronously
    per device).
    """
    out = []
    for d in range(pp):
        w = min(m, pp - d)              # deeper warmup than sync 1F1B
        tasks: List[Task] = [Task("F", i) for i in range(w)]
        nf, nb = w, 0
        while nb < m:
            if nf < m:
                tasks.append(Task("B", nb)); nb += 1
                tasks.append(Task("F", nf)); nf += 1
            else:
                tasks.append(Task("B", nb)); nb += 1
        out.append(tasks)
    return out
