"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (module import never touches jax
device state). Single-pod: (16, 16) = 256 v5e chips, axes (data, model).
Multi-pod: (2, 16, 16) = 512 chips across 2 pods, axes (pod, data, model);
the ``pod`` axis crosses DCN and carries only data parallelism.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def batch_axes(mesh) -> tuple:
    """The mesh axes that carry the batch (DP) dimension."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def make_debug_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many local devices exist (tests)."""
    return jax.make_mesh((data, model), ("data", "model"))
