"""CLI for the static analyzers.

``python -m repro.analyze graph [--smoke|--full]``
    Build every in-tree matrix cell (train + serving + degraded) on
    the analytical provider and run the full graph verifier over each
    engine, each perturbation, one compiled mega-batch over all
    engines, and the static HBM-capacity check per cell. Exit 1 on any
    finding. Pure numpy — safe for the no-jax CI image.

``python -m repro.analyze lint <paths...>``
    Run the AST contract linter over files/directories. Exit 1 on any
    finding.
"""
from __future__ import annotations

import argparse
import os
import sys
from typing import List

from repro.analyze.findings import VERIFY_ENV, Finding


def _cmd_graph(args: argparse.Namespace) -> int:
    # constructions below are verified explicitly so ALL findings are
    # collected; disable the raising construction-time hook.
    os.environ[VERIFY_ENV] = "0"
    from repro.analyze.graph import (verify_cell_memory, verify_engine,
                                     verify_megabatch,
                                     verify_perturbation)
    from repro.core.costmodel import get_cluster
    from repro.core.megabatch import MegaBatch
    from repro.core.profiler import AnalyticalProvider
    from repro.core.scenario import TRAIN
    from repro.validate.build_cache import BuildCache
    from repro.validate.degraded import degraded_matrix
    from repro.validate.sweep import (full_matrix, serving_matrix,
                                      smoke_matrix)

    cluster = get_cluster(args.cluster)
    provider = AnalyticalProvider(cluster)
    cache = BuildCache(provider)
    cells = smoke_matrix() + serving_matrix()
    if args.full:
        cells += full_matrix()

    findings: List[Finding] = []
    engines = []
    n_checked = 0
    for cell in cells:
        scenario = getattr(cell, "scenario", TRAIN)
        eng = cache.engine_for(cell)
        engines.append(eng)
        fs = verify_engine(eng)
        micro = scenario.microbatch_size(cell.strategy, cell.global_batch)
        fs += verify_cell_memory(
            cell.config(), cell.strategy, micro, cell.seq,
            cluster.chip.hbm_bytes, scenario=scenario)
        findings += [Finding(f.rule, f.message,
                             f"{cell.label()} | {f.where}")
                     for f in fs]
        n_checked += 1

    for dcell in degraded_matrix():
        eng = cache.engine_for(dcell)
        fs = verify_engine(eng)
        fs += verify_perturbation(dcell.perturb, dcell.strategy)
        findings += [Finding(f.rule, f.message,
                             f"{dcell.label()} | {f.where}")
                     for f in fs]
        n_checked += 1

    mb = MegaBatch(engines)
    mb_findings = verify_megabatch(mb)
    findings += mb_findings
    print(f"repro.analyze graph: {n_checked} cells + 1 mega-batch "
          f"program (K={mb.K}, T={mb.T}) on {cluster.name}")
    return _report(findings)


def _cmd_lint(args: argparse.Namespace) -> int:
    from repro.analyze.lint import lint_paths
    findings = lint_paths(args.paths)
    print(f"repro.analyze lint: {', '.join(args.paths)}")
    return _report(findings)


def _report(findings: List[Finding]) -> int:
    for f in findings:
        print(f"  {f}")
    if findings:
        print(f"FAIL: {len(findings)} finding(s)")
        return 1
    print("PASS: 0 findings")
    return 0


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analyze",
        description="DistSim static analysis (graph verifier + linter)")
    sub = parser.add_subparsers(dest="cmd", required=True)

    g = sub.add_parser("graph", help="verify the in-tree matrices' "
                                     "event graphs")
    g.add_argument("--smoke", action="store_true",
                   help="smoke + serving + degraded matrices (default)")
    g.add_argument("--full", action="store_true",
                   help="additionally sweep the nightly full_matrix()")
    g.add_argument("--cluster", default="a40-cluster",
                   help="cluster registry name (default: a40-cluster)")
    g.set_defaults(fn=_cmd_graph)

    lt = sub.add_parser("lint", help="AST contract linter")
    lt.add_argument("paths", nargs="+", help="files or directories")
    lt.set_defaults(fn=_cmd_lint)

    args = parser.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
