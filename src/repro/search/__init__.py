"""repro.search — cached, pruned, multi-cluster strategy search.

The paper's §6 use-case as a subsystem:

    from repro.search import SearchEngine, search_report
    engine = SearchEngine(cfg, clusters=[A40_CLUSTER, V5E_POD])
    result = engine.search(n_devices=64, global_batch=64, seq=512)
    print(format_report(search_report(result)))

``repro.core.search.grid_search`` remains as a deprecated
naive-compatible wrapper over this engine.
"""
from repro.search.cache import ProfileCache
from repro.search.engine import (SearchEngine, SearchEntry, SearchResult,
                                 SearchStats, pareto_frontier)
from repro.search.prune import (estimate_memory, hbm_headroom,
                                memory_feasible, work_lower_bound)
from repro.search.report import format_report, format_table, search_report
from repro.search.space import Candidate, enumerate_candidates

__all__ = [
    "ProfileCache", "SearchEngine", "SearchEntry", "SearchResult",
    "SearchStats", "pareto_frontier", "estimate_memory", "hbm_headroom",
    "memory_feasible", "work_lower_bound", "format_report",
    "format_table", "search_report", "Candidate", "enumerate_candidates",
]
