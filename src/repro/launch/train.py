"""Training launcher CLI.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2_1_5b \
        --smoke --steps 50 [--ckpt-dir /tmp/ckpt]

``--smoke`` trains the reduced same-family config on this host (the full
configs are for the pod dry-run / real TPU deployment, where this same
driver runs under `jax.distributed.initialize()` with the production
mesh — see repro/launch/dryrun.py for the sharding entry points).
"""
from __future__ import annotations

import argparse

import jax.numpy as jnp

from repro.configs.base import get_config, list_archs, smoke_config
from repro.models.layers import ModelOptions
from repro.train.optimizer import AdamWConfig
from repro.train.step import TrainConfig
from repro.train.train_loop import LoopConfig, fit


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2_1_5b",
                    choices=list(list_archs()))
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--save-every", type=int, default=50)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    print(f"arch={cfg.name} params={cfg.n_params()/1e6:.1f}M "
          f"(active {cfg.n_active_params()/1e6:.1f}M)")

    res = fit(
        cfg,
        opts=ModelOptions(dtype=jnp.float32, remat=False),
        tcfg=TrainConfig(
            adamw=AdamWConfig(lr=args.lr,
                              warmup_steps=max(10, args.steps // 20),
                              total_steps=args.steps),
            accum_steps=args.accum),
        loop=LoopConfig(steps=args.steps, seq_len=args.seq,
                        global_batch=args.batch, log_every=10,
                        save_every=args.save_every if args.ckpt_dir else 0,
                        ckpt_dir=args.ckpt_dir))
    print(f"done: loss {res.losses[0]:.4f} → {res.losses[-1]:.4f} "
          f"({res.steps_done} steps)")


if __name__ == "__main__":
    main()
