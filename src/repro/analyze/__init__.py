"""Static analysis over DistSim's event-graph IR and sources.

Two passes (see ``python -m repro.analyze --help``):

* :mod:`repro.analyze.graph` — structural verifier over
  ``EngineBuild``/task graphs and compiled ``MegaBatch`` programs,
  wired into construction behind the ``verify=`` flag /
  ``REPRO_VERIFY`` env var.
* :mod:`repro.analyze.lint` — AST rules for the repo's own written
  contracts (display-only ``Event.name``, cache-key completeness,
  deterministic iteration and RNG in build paths).
"""
from repro.analyze.findings import (Finding, GraphInvariantError,
                                    VERIFY_ENV, default_verify,
                                    raise_on_findings)
from repro.analyze.graph import (verify_build, verify_cell_memory,
                                 verify_engine, verify_megabatch,
                                 verify_perturbation)
from repro.analyze.lint import lint_file, lint_paths, lint_source

__all__ = [
    "Finding", "GraphInvariantError", "VERIFY_ENV", "default_verify",
    "raise_on_findings", "verify_build", "verify_cell_memory",
    "verify_engine", "verify_megabatch", "verify_perturbation",
    "lint_file", "lint_paths", "lint_source",
]
