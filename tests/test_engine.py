"""Event-flow engine: differential tests against the historical polling
scheduler, the two replay-oracle bugfixes, analytic DP replication, and
the lazy array-backed timeline stats."""
import math

import pytest

from repro.configs.base import get_config, smoke_config
from repro.core import (A40_CLUSTER, AnalyticalProvider, DistSim,
                        EventFlowEngine, Strategy)
from repro.core._polling_reference import construct_timeline_polling

CFG = get_config("gpt2_345m")
PROVIDER = AnalyticalProvider(A40_CLUSTER)

STRATS = [
    Strategy(mp=1, pp=2, dp=2, microbatches=4),
    Strategy(mp=1, pp=4, dp=1, microbatches=8, schedule="gpipe"),
    Strategy(mp=2, pp=2, dp=1, microbatches=4, schedule="interleaved",
             vpp=2),
    Strategy(mp=1, pp=1, dp=4, microbatches=2),
    Strategy(mp=2, pp=2, dp=2, microbatches=4, zero1=True),
    Strategy(mp=1, pp=2, dp=2, microbatches=4, schedule="pipedream"),
    Strategy(mp=1, pp=4, dp=2, microbatches=8, schedule="interleaved",
             vpp=3),
    Strategy(mp=1, pp=2, dp=2, microbatches=4, grad_compress=0.25),
]


def _key(tl):
    return sorted((a.device, a.name, a.kind, a.start, a.end, a.stage,
                   a.micro) for a in tl.activities)


@pytest.mark.parametrize("strat", STRATS, ids=lambda s: f"{s.label()}-"
                         f"{s.schedule}-v{s.vpp}-z{int(s.zero1)}")
def test_predict_bit_identical_to_polling_scheduler(strat):
    """Zero-noise timelines must match the seed scheduler bit-for-bit —
    the goldens-regeneration argument rests on this: any predict-side
    drift would be an engine bug, not a replay-oracle bugfix."""
    gb = strat.dp * strat.microbatches * 2
    sim = DistSim(CFG, strat, gb, 128, PROVIDER)
    new = sim.simulate().timeline()
    old = construct_timeline_polling(CFG, strat, gb, 128, PROVIDER)
    assert new.n_devices == old.n_devices
    assert _key(new) == _key(old)


def test_predict_bit_identical_with_empty_stages():
    """pp > layer count: trailing positions own no layers."""
    cfg = smoke_config(get_config("gpt2_345m"))    # 2 layers
    strat = Strategy(pp=4, microbatches=4)
    sim = DistSim(cfg, strat, 4, 64, PROVIDER)
    new = sim.simulate().timeline()
    old = construct_timeline_polling(cfg, strat, 4, 64, PROVIDER)
    assert _key(new) == _key(old)


# --------------------------------------------------------------------------
# replay-oracle bugfixes
# --------------------------------------------------------------------------

def _sim(mp=2, pp=2, dp=2, m=4, schedule="1f1b"):
    return DistSim(CFG, Strategy(mp=mp, pp=pp, dp=dp, microbatches=m,
                                 schedule=schedule), dp * m, 128, PROVIDER)


def test_clock_skew_constant_per_device():
    """Fix: clock_sigma is ONE offset per (replica, device, mp rank) per
    run, applied to every activity of that device — not an independent
    draw per activity (that's jitter, and it's already modeled)."""
    sim = _sim()
    base = sim.simulate(seeds=7).timeline().by_device()
    skew = sim.simulate(seeds=7, clock_sigma=1e-3).timeline().by_device()
    offsets = set()
    for dev in base:
        per_dev = {round(a.start - b.start, 12)
                   for a, b in zip(skew[dev], base[dev])}
        per_dev |= {round(a.end - b.end, 12)
                    for a, b in zip(skew[dev], base[dev])}
        assert len(per_dev) == 1, f"device {dev} offset not constant"
        offsets |= per_dev
    assert len(offsets) > 1          # ...but devices do disagree


def test_dp_allreduce_synchronizes_replicas():
    """Fix: a blocking all-reduce completes when the slowest participant
    does — every replica of a device slot must exit at the same time."""
    sim = _sim(dp=4)
    tl = sim.simulate(seeds=3).timeline()
    by_stage = {}
    for a in tl.activities:
        if a.kind == "AR":
            by_stage.setdefault(a.stage, []).append(a)
    assert by_stage
    for d, ars in by_stage.items():
        assert len(ars) == 4 * 2     # dp replicas x mp ranks
        assert len({round(a.start, 12) for a in ars}) == 1
        assert len({round(a.end, 12) for a in ars}) == 1


def test_ar_end_is_max_of_replica_draws():
    """The common AR end must be start + max over per-replica draws:
    strictly larger than the zero-jitter span for some seed."""
    sim = _sim(dp=4)
    pred = sim.simulate().timeline()
    pred_span = {a.stage: a.end - a.start for a in pred.activities
                 if a.kind == "AR"}
    tl = sim.simulate(seeds=11).timeline()
    spans = {a.stage: a.end - a.start for a in tl.activities
             if a.kind == "AR"}
    assert any(spans[d] > pred_span[d] for d in spans)


# --------------------------------------------------------------------------
# analytic DP replication (predict path independent of dp)
# --------------------------------------------------------------------------

def test_predict_simulates_single_replica(monkeypatch):
    sim = _sim(dp=4)
    engine = sim.engine()
    calls = []
    orig = EventFlowEngine._simulate_replica

    def counting(self, *a, **k):
        calls.append(1)
        return orig(self, *a, **k)

    monkeypatch.setattr(EventFlowEngine, "_simulate_replica", counting)
    engine.run()
    assert len(calls) == 1           # dp=4 replicated analytically
    calls.clear()
    engine.run(jitter_sigma=0.025, seed=0)
    assert len(calls) == 4           # noisy replicas diverge: all simulated


def test_replicas_identical_under_zero_noise():
    sim = _sim(dp=3, mp=1)
    tl = sim.simulate().timeline()
    pp = 2
    by_dev = tl.by_device()
    ref = [(a.name, a.kind, round(a.start, 12), round(a.end, 12))
           for a in by_dev[0]]
    for r in (1, 2):
        rep = [(a.name, a.kind, round(a.start, 12), round(a.end, 12))
               for a in by_dev[r * pp]]
        assert rep == ref


# --------------------------------------------------------------------------
# determinism + RNG hygiene
# --------------------------------------------------------------------------

def test_replay_deterministic_per_seed():
    sim = _sim()
    a = sim.simulate(seeds=5).timeline()
    b = sim.simulate(seeds=5).timeline()
    assert _key(a) == _key(b)
    c = sim.simulate(seeds=6).timeline()
    assert _key(a) != _key(c)


def test_zero_noise_replay_equals_predict():
    sim = _sim()
    pred = sim.simulate().timeline()
    rep = sim.simulate(seeds=0, jitter_sigma=0.0).timeline()
    assert _key(pred) == _key(rep)


def test_straggler_only_slows_one_device_everywhere():
    """straggler_sigma scales ALL of a device's event durations by one
    factor >= 1; batch time can only grow."""
    sim = _sim()
    pred = sim.simulate()
    slow = sim.simulate(seeds=2, jitter_sigma=0.0, straggler_sigma=0.3)
    assert slow.batch_time >= pred.batch_time


# --------------------------------------------------------------------------
# lazy timeline stats
# --------------------------------------------------------------------------

def test_lazy_stats_match_materialized():
    """batch_time/utilization computed from engine arrays must agree
    with recomputing them from the materialized activity list."""
    from repro.core.timeline import Timeline
    for strat in (Strategy(mp=2, pp=2, dp=2, microbatches=4),
                  Strategy(pp=2, dp=2, microbatches=4,
                           schedule="pipedream")):
        sim = DistSim(CFG, strat, 8, 128, PROVIDER)
        for tl in (sim.simulate().timeline(),
                   sim.simulate(seeds=1, clock_sigma=1e-4).timeline()):
            flat = Timeline(list(tl.activities), n_devices=tl.n_devices)
            assert tl.batch_time == pytest.approx(flat.batch_time,
                                                  rel=0, abs=0)
            lazy_u, flat_u = tl.utilization(), flat.utilization()
            assert set(lazy_u) == set(flat_u)
            for d in flat_u:
                assert lazy_u[d] == pytest.approx(flat_u[d], abs=1e-12)
            assert tl.bubble_fraction() == pytest.approx(
                flat.bubble_fraction(), abs=1e-12)


def test_lazy_timeline_materializes_once():
    sim = _sim()
    tl = sim.simulate().timeline()
    first = tl.activities
    assert tl.activities is first


def test_engine_cache_custom_positions_do_not_shadow_default():
    """predict(positions=custom) must not poison later positions-free
    calls: they rebuild from the sim's own positions()."""
    from repro.core.hierarchy import build_positions
    sim = _sim()
    default_bt = sim.simulate().batch_time
    # same pp*vpp stage count, different (smaller) model -> different times
    custom = build_positions(smoke_config(CFG), sim.strategy, 1, 128,
                             PROVIDER.cluster)
    custom_bt = sim.simulate(positions=custom).batch_time
    assert custom_bt != default_bt
    assert sim.simulate().batch_time == default_bt
    assert sim.engine() is not sim.engine(custom)


# --------------------------------------------------------------------------
# batched multi-seed replay: differential oracle (batched == looped)
# --------------------------------------------------------------------------

BATCH_CASES = [
    ("gpt2_345m", Strategy(mp=1, pp=2, dp=2, microbatches=4), 1),
    ("gpt2_345m", Strategy(mp=1, pp=2, dp=2, microbatches=4), 2),
    ("gpt2_345m", Strategy(mp=1, pp=4, dp=1, microbatches=8,
                           schedule="gpipe"), 2),
    ("gpt2_345m", Strategy(mp=2, pp=2, dp=1, microbatches=4,
                           schedule="interleaved", vpp=2), 4),
    ("gpt2_345m", Strategy(mp=1, pp=2, dp=2, microbatches=4,
                           schedule="pipedream"), 2),
    ("gpt2_345m", Strategy(mp=2, pp=2, dp=2, microbatches=4,
                           zero1=True), 2),
    ("bert_large", Strategy(mp=2, pp=2, dp=2, microbatches=4), 2),
    ("t5_large", Strategy(mp=1, pp=2, dp=2, microbatches=4), 4),
]


@pytest.mark.parametrize(
    "arch,strat,S", BATCH_CASES,
    ids=lambda v: v if isinstance(v, str) else (
        f"{v.label()}-{v.schedule}" if isinstance(v, Strategy) else f"S{v}"))
def test_batched_replay_bit_identical_to_looped(arch, strat, S):
    """run_batched(seeds) must be bit-identical PER SEED to sequential
    run(seed=s) calls — batch times, per-device busy seconds, and every
    materialized activity timestamp. This is the oracle that lets the
    validate sweep switch to the batched path without regenerating
    goldens."""
    gb = strat.dp * strat.microbatches * 2
    sim = DistSim(get_config(arch), strat, gb, 128, PROVIDER)
    engine = sim.engine()
    seeds = list(range(S))
    batch = engine.run_batched(seeds, jitter_sigma=0.025,
                               straggler_sigma=0.05, clock_sigma=1e-4)
    assert len(batch) == S
    assert batch.seeds == seeds
    for i, s in enumerate(seeds):
        tl = engine.run(jitter_sigma=0.025, straggler_sigma=0.05,
                        clock_sigma=1e-4, seed=s)
        assert float(batch.batch_times[i]) == tl.batch_time
        assert batch.n_devices == tl.n_devices
        for d in range(tl.n_devices):
            assert float(batch.busy[i][d]) == tl._busy[d]
        assert _key(batch.timeline(i)) == _key(tl)


def test_batched_predict_lane_matches_predict():
    """seeds=None is the zero-noise predict lane — same numbers as
    run(), down to the bit."""
    sim = _sim(dp=3, mp=2)
    engine = sim.engine()
    batch = engine.run_batched(None)
    tl = engine.run()
    assert batch.seeds == [None] and batch.n_sim == 1
    assert float(batch.batch_times[0]) == tl.batch_time
    assert _key(batch.timeline(0)) == _key(tl)


def test_batched_zero_noise_seed_equals_predict():
    """A seeded lane with all sigmas 0 is still the deterministic
    predict path (run() ignores the seed without noise; so must the
    batch)."""
    sim = _sim()
    engine = sim.engine()
    batch = engine.run_batched([5], jitter_sigma=0.0)
    assert _key(batch.timeline(0)) == _key(engine.run())


def test_batched_single_lane_matches_polling_reference():
    """S=1 batched replay at zero noise must reproduce the frozen seed
    scheduler bit-for-bit on a small cell (under noise the engine
    intentionally diverges: it fixes the polling oracle's per-activity
    clock draws and non-synchronizing all-reduce)."""
    cfg = smoke_config(get_config("gpt2_345m"))
    strat = Strategy(mp=1, pp=2, dp=2, microbatches=4)
    sim = DistSim(cfg, strat, 8, 64, PROVIDER)
    batch = sim.engine().run_batched([0], jitter_sigma=0.0)
    old = construct_timeline_polling(cfg, strat, 8, 64, PROVIDER)
    assert batch.n_devices == old.n_devices
    assert _key(batch.timeline(0)) == _key(old)


def test_batched_stats_match_lane_timelines():
    """TimelineBatch utilization/bubble arrays must agree with the
    per-lane LazyTimeline views (which in turn match materialized
    recomputation, covered above)."""
    sim = _sim(dp=2)
    batch = sim.simulate(seeds=(0, 1), clock_sigma=1e-4).batch
    util = batch.utilization()
    bub = batch.bubble_fraction()
    for i in range(len(batch)):
        lane = batch.timeline(i)
        lane_util = lane.utilization()
        for d in range(batch.n_devices):
            assert util[i, d] == lane_util[d]
        assert bub[i] == pytest.approx(lane.bubble_fraction(), abs=1e-12)


def test_batched_empty_seedlist_raises():
    with pytest.raises(ValueError, match="seed"):
        _sim().engine().run_batched([])


# --------------------------------------------------------------------------
# failure modes
# --------------------------------------------------------------------------

def test_deadlocked_schedule_raises():
    """A schedule whose head task's input can never arrive must raise,
    not hang or silently drop tasks."""
    sim = _sim(pp=2, dp=1, m=2)
    engine = sim.engine()
    # reverse device 1's task list: its first task now needs an arrival
    # that is only produced after its own later tasks ran
    engine.task_isf[1] = engine.task_isf[1][::-1]
    engine.task_pos[1] = engine.task_pos[1][::-1]
    engine.task_micro[1] = engine.task_micro[1][::-1]
    engine.task_name[1] = engine.task_name[1][::-1]
    engine.task_p2p_name[1] = engine.task_p2p_name[1][::-1]
    with pytest.raises(RuntimeError, match="deadlock"):
        engine.run()
    with pytest.raises(RuntimeError, match="deadlock"):
        engine.run_batched([0, 1], jitter_sigma=0.025)


def test_nan_free_timelines():
    sim = _sim(dp=2)
    for tl in (sim.simulate().timeline(), sim.simulate(seeds=0).timeline()):
        for a in tl.activities:
            assert not math.isnan(a.start) and not math.isnan(a.end)
            assert a.end >= a.start - 1e-12
