"""Architecture/config system.

``ArchConfig`` is the single source of truth consumed by three layers:
  * ``repro.models``   — builds the actual JAX model (init + apply),
  * ``repro.core.modelgraph`` — builds the DistSim layer graph (events),
  * ``repro.launch``   — dry-run lowering of every (arch x shape x mesh) cell.

All assigned architectures are registered here via their config modules; use
``get_config(name)`` / ``list_archs()``.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int           # per-expert hidden size
    capacity_factor: float = 1.25
    router_dtype: str = "float32"


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128          # N in SSD
    head_dim: int = 64          # P in SSD
    chunk: int = 256            # SSD chunk length
    d_conv: int = 4             # depthwise conv width
    expand: int = 2             # d_inner = expand * d_model


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One (input-shape) cell assigned to an architecture."""
    name: str                   # train_4k / prefill_32k / decode_32k / long_500k
    seq_len: int
    global_batch: int
    kind: str                   # "train" | "prefill" | "decode"

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


# The four LM shapes shared by all assigned architectures.
SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int                # 0 for attention-free
    n_kv_heads: int
    d_ff: int                   # dense FFN hidden (0 for attn-free SSD blocks)
    vocab: int
    # --- options ---
    qkv_bias: bool = False
    mlp_gelu: bool = False                    # 2-matrix GELU MLP (BERT/GPT-2 era)
    sliding_window: Optional[int] = None      # SWA width (tokens)
    rope_theta: float = 1e4
    tie_embeddings: bool = False
    enc_dec: bool = False                     # whisper-style encoder-decoder
    vision_stub: bool = False                 # VLM: patch-embedding input stub
    audio_stub: bool = False                  # audio: frame-embedding input stub
    moe: Optional[MoEConfig] = None
    # MoE applied to every `moe_period`-th FFN (1 = all layers; jamba = 2)
    moe_period: int = 1
    ssm: Optional[SSMConfig] = None
    # hybrid (jamba): one attention layer per `hybrid_period` layers, the rest SSM
    hybrid_period: int = 0
    # which assigned shapes apply (None = all); long_500k must be explicitly
    # included (sub-quadratic archs only).
    shapes: Tuple[str, ...] = ("train_4k", "prefill_32k", "decode_32k")
    # citation / provenance string from the assignment table
    source: str = ""

    # ---- derived ----
    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def is_attention_free(self) -> bool:
        return self.n_heads == 0

    def attn_layer_indices(self) -> Tuple[int, ...]:
        """Indices of attention layers (hybrid archs interleave)."""
        if self.is_attention_free:
            return ()
        if self.hybrid_period:
            # jamba: 1 attention layer per period, at position period//2
            off = self.hybrid_period // 2
            return tuple(i for i in range(self.n_layers)
                         if i % self.hybrid_period == off)
        return tuple(range(self.n_layers))

    def n_params(self) -> int:
        """Total parameter count (embedding + blocks + head)."""
        from repro.core.modelgraph import count_params
        return count_params(self)

    def n_active_params(self) -> int:
        from repro.core.modelgraph import count_params
        return count_params(self, active_only=True)


_REGISTRY = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


_ASSIGNED = (
    "whisper_tiny", "qwen2_1_5b", "h2o_danube_1_8b", "mistral_large_123b",
    "phi3_medium_14b", "mamba2_2_7b", "qwen3_moe_30b_a3b", "dbrx_132b",
    "qwen2_vl_72b", "jamba_v0_1_52b",
)
_PAPER = ("bert_large", "gpt2_345m", "t5_large", "bert_exlarge", "gpt_145b")


_LOADED = False


def _ensure_loaded() -> None:
    global _LOADED
    if _LOADED:
        return
    for mod in _ASSIGNED + _PAPER:
        importlib.import_module(f"repro.configs.{mod}")
    _LOADED = True


def get_config(name: str) -> ArchConfig:
    _ensure_loaded()
    key = name.replace("-", "_").replace(".", "_")
    if key not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[key]


def list_archs(assigned_only: bool = False) -> Tuple[str, ...]:
    _ensure_loaded()
    return _ASSIGNED if assigned_only else tuple(sorted(_REGISTRY))


def arch_shapes(cfg: ArchConfig):
    """The ShapeConfigs that apply to this architecture."""
    return [SHAPES[s] for s in cfg.shapes]


def smoke_config(cfg: ArchConfig) -> ArchConfig:
    """A reduced same-family config for CPU smoke tests."""
    moe = None
    if cfg.moe:
        moe = MoEConfig(n_experts=min(4, cfg.moe.n_experts),
                        top_k=min(2, cfg.moe.top_k), d_ff_expert=64)
    ssm = None
    if cfg.ssm:
        ssm = SSMConfig(d_state=16, head_dim=16, chunk=32, expand=2)
    n_layers = 4 if cfg.hybrid_period else 2
    n_heads = 0 if cfg.is_attention_free else 4
    n_kv = 0 if cfg.is_attention_free else min(cfg.n_kv_heads, 2)
    return dataclasses.replace(
        cfg, name=cfg.name + "_smoke", n_layers=n_layers, d_model=64,
        n_heads=n_heads, n_kv_heads=n_kv, d_ff=0 if cfg.d_ff == 0 else 128,
        vocab=256, sliding_window=32 if cfg.sliding_window else None,
        moe=moe, ssm=ssm, hybrid_period=2 if cfg.hybrid_period else 0,
    )
