"""Shared profile/event cache for strategy search.

The paper's core trick (Observation 1): events are identical across
devices, microbatches — and, crucially for search, across *candidate
strategies*. Two strategies with the same MP degree share every layer
compute event; collectives recur across grid points. A ``ProfileCache``
holds one profiling provider per target cluster and is shared by every
candidate the engine scores, so each unique event is cost-evaluated
once per search instead of once per candidate.

Event identity is structural (``Event`` is a frozen dataclass keyed on
kind/op/sharded shapes/participants/scope), so the provider's dict
cache IS the unique-event signature cache.
"""
from __future__ import annotations

from typing import Callable, Dict, Iterable, Mapping

from repro.core.costmodel import ClusterSpec
from repro.core.profiler import AnalyticalProvider, Provider


class ProfileCache:
    """One provider (and thus one event-time cache) per cluster.

    Pass ``store`` (a :class:`repro.store.ProfileStore` or path) to
    persist the dedup layer across search invocations: per-cluster
    build caches become :class:`repro.store.PersistentBuildCache`\\ s,
    so a fresh process re-running the same search loads the profiled
    events + engine builds from disk instead of re-deriving them."""

    def __init__(self, providers: Mapping[str, Provider], store=None):
        self.providers: Dict[str, Provider] = dict(providers)
        self.store = store
        self._build_caches: Dict[str, object] = {}

    @classmethod
    def for_clusters(cls, clusters: Iterable[ClusterSpec],
                     provider_factory: Callable[[ClusterSpec], Provider]
                     = AnalyticalProvider, store=None) -> "ProfileCache":
        return cls({c.name: provider_factory(c) for c in clusters},
                   store=store)

    @classmethod
    def from_provider(cls, provider: Provider,
                      store=None) -> "ProfileCache":
        return cls({provider.cluster.name: provider}, store=store)

    def provider(self, cluster: ClusterSpec) -> Provider:
        return self.providers[cluster.name]

    def build_cache(self, cluster: ClusterSpec):
        """Per-cluster :class:`repro.validate.build_cache.BuildCache`
        bound to that cluster's provider — the positions/build/engine
        dedup layer the mega-batch search path compiles from. Persists
        with this ProfileCache, so repeat searches reuse engines (and
        profile nothing). Imported lazily: repro.validate pulls in the
        sweep stack, which search-only callers don't need."""
        bc = self._build_caches.get(cluster.name)
        if bc is None:
            if self.store is not None:
                from repro.store.persistent import PersistentBuildCache
                bc = PersistentBuildCache(self.provider(cluster),
                                          self.store)
            else:
                from repro.validate.build_cache import BuildCache
                bc = BuildCache(self.provider(cluster))
            self._build_caches[cluster.name] = bc
        return bc

    def flush(self) -> int:
        """Persist newly-profiled events of every store-backed build
        cache (no-op without a store). Returns events written."""
        n = 0
        for bc in self._build_caches.values():
            if hasattr(bc, "flush"):
                n += bc.flush()
        return n

    @property
    def clusters(self) -> list:
        return [p.cluster for p in self.providers.values()]

    # ---- aggregate accounting across clusters ----
    @property
    def evaluations(self) -> int:
        return sum(p.stats.evaluations for p in self.providers.values())

    @property
    def hits(self) -> int:
        return sum(p.stats.hits for p in self.providers.values())

    @property
    def unique_events(self) -> int:
        return sum(p.cache_size for p in self.providers.values())

    def reset_stats(self) -> None:
        for p in self.providers.values():
            p.stats.reset()

    def snapshot(self) -> Dict[str, float]:
        lookups = self.evaluations + self.hits
        return {
            "unique_events": self.unique_events,
            "evaluations": self.evaluations,
            "hits": self.hits,
            "hit_rate": self.hits / lookups if lookups else 0.0,
        }
