"""Parallel sweep executor (tentpole of the sweep-scale subsystem).

The accuracy sweep is embarrassingly parallel per cell: cells only
interact through the shared profiling provider (the paper's
unique-event dedup) and the shared build cache. ``run_parallel`` fans
cells out over worker processes, each with its OWN provider shard
(seeded with the parent's already-profiled events) and its own
:class:`~repro.validate.build_cache.BuildCache`, then merges the
shards back deterministically:

* **results** are reassembled in cell order, so the merged
  ``SweepResult`` — and its ``report.dump()`` JSON — is bit-identical
  to the serial sweep's (every per-cell number is a deterministic
  function of the cell + provider, not of scheduling);
* **event caches** merge by set-union with incumbent-wins semantics
  (values are identical across shards for a deterministic provider),
  so ``ProviderStats.evaluations`` afterwards equals the serial
  sweep's unique-event count: an event profiled by two shards still
  counts ONCE, exactly as the paper's Table 3 accounting requires;
* **hits** absorb the remaining shard lookups, so ``lookups`` stays
  the true number of provider queries performed.

Workers are spawned via fork where available (cheap, no re-import);
the payloads (cells, provider, thresholds) are all plain picklable
dataclasses, so spawn-only platforms work too.
"""
from __future__ import annotations

import multiprocessing
import sys
from concurrent.futures import ProcessPoolExecutor
from typing import List, Optional, Sequence, Tuple

from repro.core.profiler import Provider
from repro.validate.build_cache import BuildCache, BuildCacheStats
from repro.validate.sweep import (CellResult, Thresholds, ValidationCell,
                                  run_cell)


def _mp_context():
    """fork is the cheap path (no re-import in workers), but forking a
    process that already initialized JAX's thread pools can deadlock —
    fall back to spawn whenever jax is loaded (e.g. under the full
    test session). The sweep itself is numpy-only either way."""
    methods = multiprocessing.get_all_start_methods()
    if "fork" in methods and "jax" not in sys.modules:
        return multiprocessing.get_context("fork")
    return multiprocessing.get_context("spawn")


def _chunk(n: int, jobs: int) -> List[range]:
    """Contiguous near-even index chunks. Contiguity matters: the full
    matrix lists each (model, strategy) pair's four schedules
    consecutively, so keeping neighbors together maximizes each
    worker's build-cache hit rate."""
    base, extra = divmod(n, jobs)
    out, start = [], 0
    for w in range(jobs):
        size = base + (1 if w < extra else 0)
        out.append(range(start, start + size))
        start += size
    return [r for r in out if len(r)]


def _run_shard(payload) -> Tuple[List[Tuple[int, CellResult]], dict, int,
                                 BuildCacheStats]:
    """One worker: run a slice of cells against a private provider
    shard; report results, the shard's newly-profiled events, its
    lookup count and its build-cache accounting."""
    (provider, indexed_cells, seeds, thresholds, jitter_sigma, batched,
     use_cache) = payload
    provider.stats.reset()
    known = set(provider.cache_snapshot())
    cache = BuildCache(provider) if use_cache else None
    results = [(idx, run_cell(cell, provider, seeds, thresholds,
                              jitter_sigma, batched=batched, cache=cache))
               for idx, cell in indexed_cells]
    delta = {e: t for e, t in provider.cache_snapshot().items()
             if e not in known}
    cache_stats = cache.stats if cache is not None else BuildCacheStats()
    return results, delta, provider.stats.lookups, cache_stats


def run_parallel(cells: Sequence[ValidationCell], provider: Provider,
                 seeds: Sequence[int] = (0, 1, 2),
                 thresholds: Optional[Thresholds] = None,
                 jitter_sigma: float = 0.025, jobs: int = 2,
                 batched: bool = True, use_cache: bool = True,
                 cache_stats: Optional[BuildCacheStats] = None
                 ) -> List[CellResult]:
    """Evaluate ``cells`` across ``jobs`` worker processes.

    Mutates ``provider`` exactly as the serial sweep would: its event
    cache gains the union of all shards' profiled events and its stats
    advance by the serial-equivalent (evaluations += newly unique,
    hits += remaining lookups). Pass ``cache_stats`` to additionally
    accumulate the shards' build-cache accounting.
    """
    thresholds = thresholds or Thresholds()
    cells = list(cells)
    jobs = max(1, min(int(jobs), len(cells) or 1))
    if jobs == 1:
        cache = BuildCache(provider) if use_cache else None
        out = [run_cell(c, provider, seeds, thresholds, jitter_sigma,
                        batched=batched, cache=cache)
               for c in cells]
        if cache is not None and cache_stats is not None:
            cache_stats.merge(cache.stats)
        return out

    payloads = []
    for idx_range in _chunk(len(cells), jobs):
        indexed = [(i, cells[i]) for i in idx_range]
        payloads.append((provider, indexed, tuple(seeds), thresholds,
                         jitter_sigma, batched, use_cache))

    with ProcessPoolExecutor(max_workers=len(payloads),
                             mp_context=_mp_context()) as pool:
        shards = list(pool.map(_run_shard, payloads))

    results: List[Optional[CellResult]] = [None] * len(cells)
    new_events = 0
    total_lookups = 0
    for shard_results, delta, lookups, shard_cache_stats in shards:
        for idx, res in shard_results:
            results[idx] = res
        new_events += provider.merge_cache(delta)
        total_lookups += lookups
        if cache_stats is not None:
            cache_stats.merge(shard_cache_stats)
    # serial-equivalent accounting: each unique event counts once no
    # matter how many shards profiled it; everything else was a reuse
    provider.stats.evaluations += new_events
    provider.stats.hits += total_lookups - new_events
    assert all(r is not None for r in results)
    return results
