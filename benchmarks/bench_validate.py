"""Paper-fidelity validation sweep entry point (CI: validate-smoke job).

Runs predict() vs multi-seed replay() over the accuracy matrix, writes
``validation_report.json`` (uploaded as a CI artifact), prints the
pass/fail table plus the unique-event / build-cache accounting, and
exits non-zero if any non-xfail cell exceeds the paper's §5 thresholds.

In smoke mode it additionally gates the sweep-scale subsystem: the
shared build cache must make a build-dominated cell family >= 3x
faster to re-sweep than the uncached path, with a bit-identical report
(same ``dump()`` JSON) — the wall-time claim behind running the
extended ``--full`` matrix nightly with ``--jobs 4``.

    PYTHONPATH=src python benchmarks/bench_validate.py --smoke
    PYTHONPATH=src python benchmarks/bench_validate.py --full --jobs 4
    PYTHONPATH=src python benchmarks/bench_validate.py --update-goldens
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import subprocess
import sys
import tempfile
import time

from repro.core import AnalyticalProvider, get_cluster
from repro.validate import (BuildCache, Thresholds, full_matrix,
                            run_sweep, smoke_matrix)
from repro.validate.report import (dumps, format_validation_report, save)

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), os.pardir, "tests",
                           "goldens", "validation_smoke.json")
GATE_CACHE_SPEEDUP = 3.0
GATE_STORE_SPEEDUP = 3.0


def _best_of(fn, n=3):
    best, out = float("inf"), None
    for _ in range(n):
        t0 = time.perf_counter()
        res = fn()
        dt = time.perf_counter() - t0
        if dt < best:
            best, out = dt, res
    return best, out


def cache_gate(cluster: str) -> dict:
    """Build-cache effectiveness gate on a build-dominated family: the
    4 gpt_145b predict-scale cells of the full matrix (ONE strategy
    under the four schedules — the recurrence the cache dedups:
    gpipe/1f1b/pipedream share a build, interleaved adds its vpp=2
    one). Warm cached re-sweep must be >= 3x faster than the uncached
    sweep AND produce a bit-identical report."""
    cells = [c for c in full_matrix()
             if c.arch == "gpt_145b" and c.strategy.pp == 8]
    assert len(cells) == 4, "gate family drifted; fix the filter"
    seeds = (0, 1, 2)

    t_uncached, ref = _best_of(
        lambda: run_sweep(cells, cluster=cluster, seeds=seeds,
                          cache=False))
    provider = AnalyticalProvider(get_cluster(cluster))
    cache = BuildCache(provider)
    run_sweep(cells, provider=provider, seeds=seeds, cache=cache)  # warm
    t_warm, warm = _best_of(
        lambda: run_sweep(cells, provider=provider, seeds=seeds,
                          cache=cache))
    identical = dumps(ref) == dumps(warm)
    return {
        "cells": len(cells),
        "uncached_s": t_uncached,
        "warm_cached_s": t_warm,
        "speedup": t_uncached / t_warm if t_warm else float("inf"),
        "required_speedup": GATE_CACHE_SPEEDUP,
        "bit_identical": identical,
        "cache": cache.snapshot(),
    }


# Child of store_gate(): one MeasuredProvider sweep in a FRESH python
# process, wall time measured inside (imports excluded), result
# reported as JSON on stdout.
_STORE_GATE_CHILD = """\
import json, sys, time
sys.path.insert(0, sys.argv[1])
import repro.core
import repro.store                 # hoist run_sweep's lazy import
from repro.core import get_cluster
from repro.core.profiler import MeasuredProvider
from repro.validate import run_sweep
from repro.validate.sweep import _cell
from repro.validate.report import dumps

cluster, store = sys.argv[2], sys.argv[3]
cells = [_cell("gpt2_345m", 1, 2, 2, 4, "1f1b", smoke=True, seq=128)]
provider = MeasuredProvider(get_cluster(cluster), reps=1)
t0 = time.perf_counter()
result = run_sweep(cells, provider=provider, seeds=(0, 1), store=store)
wall = time.perf_counter() - t0
json.dump({"wall_s": wall, "lookups": provider.stats.lookups,
           "evaluations": provider.stats.evaluations,
           "report": dumps(result)}, sys.stdout)
"""


def _store_gate_child(cluster: str, store_path: str) -> dict:
    src = os.path.join(os.path.dirname(__file__), os.pardir, "src")
    out = subprocess.run(
        [sys.executable, "-c", _STORE_GATE_CHILD, src, cluster,
         store_path], capture_output=True, text=True)
    if out.returncode != 0:
        raise RuntimeError(f"store gate child failed:\n{out.stderr}")
    return json.loads(out.stdout)


def store_gate(cluster: str) -> dict:
    """Persistent-store gate over a MEASURED profile — the economy the
    paper's Observation 1 is actually about: the cold child jits and
    times real op groups on this host (the expensive profiling the
    analytic provider only emulates), the warm child is a FRESH
    process re-sweeping the same cell from the store. The warm run
    must be >= 3x faster (observed ~100x), perform ZERO provider
    evaluations — times and builds come entirely from disk — and
    reproduce the cold report byte-for-byte. Every run is its own
    subprocess, so in-process caches can't help."""
    with tempfile.TemporaryDirectory() as d:
        store = os.path.join(d, "store")
        cold = _store_gate_child(cluster, store)
        t_warm, warm = float("inf"), None
        for _ in range(2):
            w = _store_gate_child(cluster, store)
            if w["wall_s"] < t_warm:
                t_warm, warm = w["wall_s"], w
    return {
        "cold_s": cold["wall_s"],
        "warm_s": t_warm,
        "speedup": cold["wall_s"] / t_warm if t_warm else float("inf"),
        "required_speedup": GATE_STORE_SPEEDUP,
        "bit_identical": warm["report"] == cold["report"],
        "warm_evaluations": warm["evaluations"],
        "warm_lookups": warm["lookups"],
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    matrix = ap.add_mutually_exclusive_group()
    matrix.add_argument("--smoke", action="store_true",
                        help="CI matrix (models x schedules x strategies;"
                             " the default)")
    matrix.add_argument("--full", action="store_true",
                        help="nightly-scale cross product incl. the "
                             "predict-scale 52-145B cells")
    ap.add_argument("--seeds", default="0,1,2",
                    help="comma-separated replay seeds")
    ap.add_argument("--cluster", default="a40-cluster")
    ap.add_argument("--jitter", type=float, default=0.025,
                    help="replay per-event jitter sigma")
    ap.add_argument("--jobs", type=int, default=1,
                    help="worker processes for the sweep (cells fan out "
                         "with per-worker provider shards; the merged "
                         "report is bit-identical to --jobs 1)")
    ap.add_argument("--no-cache", action="store_true",
                    help="disable the shared build cache (A/B baseline; "
                         "results are bit-identical either way)")
    ap.add_argument("--store", default="",
                    help="persistent profile-store directory: event "
                         "times + engine builds are served from and "
                         "written back to disk, shared across runs and "
                         "processes (results stay bit-identical)")
    ap.add_argument("--batch-time-threshold", type=float, default=None)
    ap.add_argument("--activity-threshold", type=float, default=None)
    ap.add_argument("--out", default="validation_report.json",
                    help="report path ('' to skip writing)")
    ap.add_argument("--update-goldens", action="store_true",
                    help=f"rewrite {os.path.normpath(GOLDEN_PATH)}")
    ap.add_argument("--sequential", action="store_true",
                    help="legacy one-replay-per-seed path with "
                         "materialized-activity metrics (A/B baseline; "
                         "the default is one batched replay per cell)")
    args = ap.parse_args()
    if args.update_goldens and (
            args.full or args.seeds != "0,1,2"
            or args.cluster != "a40-cluster" or args.jitter != 0.025
            or args.batch_time_threshold is not None
            or args.activity_threshold is not None):
        ap.error("--update-goldens pins the smoke matrix with default "
                 "seeds/cluster/jitter/thresholds — tests/"
                 "test_validation.py hard-codes them; drop the overrides")

    cells = full_matrix() if args.full else smoke_matrix()
    seeds = tuple(int(s) for s in args.seeds.split(","))
    thr = Thresholds()
    if args.batch_time_threshold is not None:
        thr = dataclasses.replace(
            thr, batch_time=args.batch_time_threshold,
            batch_time_worst=1.5 * args.batch_time_threshold)
    if args.activity_threshold is not None:
        thr = dataclasses.replace(thr, activity=args.activity_threshold)

    provider = AnalyticalProvider(get_cluster(args.cluster))
    store = args.store or None
    if store is not None:
        # run_sweep builds the PersistentBuildCache itself (it must be
        # store-backed); the in-memory instance below would conflict
        cache = None
        cache_arg = not args.no_cache
    else:
        cache = None if args.no_cache else BuildCache(provider)
        cache_arg = cache if cache is not None else False
    t0 = time.perf_counter()
    result = run_sweep(cells, provider=provider, seeds=seeds,
                       thresholds=thr, jitter_sigma=args.jitter,
                       batched=not args.sequential,
                       cache=cache_arg, jobs=args.jobs, store=store)
    wall = time.perf_counter() - t0

    print(format_validation_report(result))
    mode = ("sequential replay" if args.sequential else "batched replay")
    print(f"\nswept {len(result.cells)} cells x {len(seeds)} seeds "
          f"in {wall:.2f}s ({len(result.cells) / wall:.1f} cells/s, "
          f"{mode}, jobs={max(1, args.jobs)}, "
          f"cache={'off' if args.no_cache else 'on'})")
    ps = provider.stats
    print(f"provider: {ps.evaluations} unique events profiled, "
          f"{ps.hits} reuses ({100 * ps.hit_rate:.1f}% hit rate)")
    if store is not None:
        print(f"store: {store} ({provider.cache_size} events resident)")
    if cache is not None:
        cs = cache.stats
        print(f"build cache: positions {cs.positions_hits}h/"
              f"{cs.positions_misses}m, builds {cs.build_hits}h/"
              f"{cs.build_misses}m, engines {cs.engine_hits}h/"
              f"{cs.engine_misses}m")

    if args.update_goldens:
        path = os.path.normpath(GOLDEN_PATH)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        save(result, path)
        print(f"goldens written to {path}")
    if args.out:
        save(result, args.out)
        print(f"report written to {args.out}")

    failed = False
    if not result.passed:
        fails = ", ".join(c.cell.label() for c in result.failures)
        print(f"validate/ERROR: thresholds exceeded on {fails}",
              file=sys.stderr)
        failed = True

    if not args.full and not args.update_goldens:
        gate = cache_gate(args.cluster)
        print(f"\ncache gate — {gate['cells']} gpt_145b cells "
              f"(1 strategy x 4 schedules): "
              f"uncached {gate['uncached_s'] * 1e3:.1f}ms, "
              f"warm cached {gate['warm_cached_s'] * 1e3:.1f}ms = "
              f"{gate['speedup']:.1f}x (gate: "
              f"{GATE_CACHE_SPEEDUP:.0f}x), bit-identical: "
              f"{gate['bit_identical']}")
        if not gate["bit_identical"]:
            print("validate/ERROR: cached sweep report differs from "
                  "uncached", file=sys.stderr)
            failed = True
        if gate["speedup"] < GATE_CACHE_SPEEDUP:
            print(f"validate/ERROR: warm-cache speedup "
                  f"{gate['speedup']:.1f}x < {GATE_CACHE_SPEEDUP}x",
                  file=sys.stderr)
            failed = True

        sg = store_gate(args.cluster)
        print(f"store gate — fresh-process re-sweep from a warm store: "
              f"cold {sg['cold_s'] * 1e3:.1f}ms, "
              f"warm {sg['warm_s'] * 1e3:.1f}ms = "
              f"{sg['speedup']:.1f}x (gate: {GATE_STORE_SPEEDUP:.0f}x), "
              f"bit-identical: {sg['bit_identical']}, "
              f"warm evaluations: {sg['warm_evaluations']}")
        if not sg["bit_identical"]:
            print("validate/ERROR: store-served sweep report differs "
                  "from the cold run", file=sys.stderr)
            failed = True
        if sg["warm_evaluations"]:
            print(f"validate/ERROR: warm store still profiled "
                  f"{sg['warm_evaluations']} events", file=sys.stderr)
            failed = True
        if sg["speedup"] < GATE_STORE_SPEEDUP:
            print(f"validate/ERROR: warm-store speedup "
                  f"{sg['speedup']:.1f}x < {GATE_STORE_SPEEDUP}x",
                  file=sys.stderr)
            failed = True

    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
