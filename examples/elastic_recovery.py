"""Fault-tolerance demo: checkpoint/restart + elastic re-planning.

Trains a tiny model, kills a "node" mid-run, restores from the last
checkpoint, re-plans the mesh for the surviving device count with
DistSim picking the new best strategy — the paper's §6 search applied
to failure recovery.

    PYTHONPATH=src python examples/elastic_recovery.py
"""
import tempfile

from repro.configs.base import get_config, smoke_config
from repro.core import A40_CLUSTER, AnalyticalProvider
from repro.train.fault_tolerance import (HeartbeatMonitor, replan_mesh,
                                         run_with_recovery)
from repro.train.train_loop import LoopConfig, fit


def main():
    cfg = smoke_config(get_config("qwen2_1_5b"))

    # --- phase 1: training with an injected failure -------------------
    with tempfile.TemporaryDirectory() as d:
        print("== training with a simulated failure at step 25 ==")
        state = {"last": 0}

        def step_fn(s):
            pass                                  # stand-in compute

        def save_fn(s):
            state["last"] = s

        def restore_fn():
            return state["last"]

        steps, recov = run_with_recovery(40, step_fn, save_fn, restore_fn,
                                         save_every=10, failure_at=25)
        print(f"completed {steps} steps with {recov} recovery "
              f"(≤10 steps re-executed)\n")

        # real checkpointed training (short)
        r1 = fit(cfg, loop=LoopConfig(steps=10, seq_len=32, global_batch=2,
                                      save_every=5, ckpt_dir=d),
                 verbose=False)
        r2 = fit(cfg, loop=LoopConfig(steps=14, seq_len=32, global_batch=2,
                                      save_every=5, ckpt_dir=d),
                 verbose=False)
        print(f"real run: resumed from step {r2.resumed_from}, "
              f"loss {r2.losses[-1]:.3f}\n")

    # --- phase 2: elastic re-plan after losing nodes ------------------
    print("== elastic re-plan: 256 devices, 13 fail ==")
    monitor = HeartbeatMonitor(256, dead_after_s=10)
    for w in range(256):
        monitor.heartbeat(w, 1.0, now=0.0)
    for w in range(243):                          # 13 workers go silent
        monitor.heartbeat(w, 1.0, now=20.0)
    dead = monitor.mark_dead(now=25.0)    # detect (pure query) + transition
    print(f"dead workers: {len(dead)} → {monitor.alive_count()} survive")
    plan = replan_mesh(monitor.alive_count(), model_parallel=16)
    print(f"new mesh: data={plan.data} x model={plan.model} "
          f"({plan.devices} devices used)")

    # DistSim picks the best strategy for the new world size
    from repro.search import ProfileCache, SearchEngine
    provider = AnalyticalProvider(A40_CLUSTER)
    engine = SearchEngine(get_config("bert_large"),
                          cache=ProfileCache.from_provider(provider),
                          prune=False, check_memory=False)
    best = engine.search(plan.devices, 16, 512).best()
    print(f"DistSim re-planned strategy: {best.strategy.label()} "
          f"@ {best.iters_per_s:.2f} it/s")


if __name__ == "__main__":
    main()
