"""Deterministic synthetic LM data pipeline.

Production shape without external deps: seeded per-shard streams,
sharded batches (each DP rank materializes only its slice), background
prefetch, and exact mid-epoch resumability via (seed, step) — a restart
resumes the stream at the same position (required for checkpoint/restart
correctness; see tests/test_data.py).

The token distribution is a Zipfian unigram mix with a deterministic
"grammar" (next-token depends on previous token) so the loss actually
decreases during the example runs.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    vocab: int = 32000
    seq_len: int = 512
    global_batch: int = 8
    shard_index: int = 0       # this host's DP shard
    shard_count: int = 1
    prefetch: int = 2


def _batch_rng(cfg: DataConfig, step: int) -> np.random.Generator:
    # independent stream per (seed, step, shard) → exact resumability
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, cfg.shard_index]))


def synth_batch(cfg: DataConfig, step: int) -> Dict[str, np.ndarray]:
    rng = _batch_rng(cfg, step)
    b = cfg.global_batch // cfg.shard_count
    v = cfg.vocab
    # Zipf unigram + first-order "grammar": tok[t] ~ f(tok[t-1])
    base = rng.zipf(1.3, size=(b, cfg.seq_len)).astype(np.int64)
    toks = (base + 31 * np.roll(base, 1, axis=1)) % (v - 2) + 1
    tokens = toks.astype(np.int32)
    labels = np.roll(tokens, -1, axis=1).astype(np.int32)
    labels[:, -1] = -1                       # no target for last position
    return {"tokens": tokens, "labels": labels}


class DataLoader:
    """Background-prefetching iterator over synthetic batches."""

    def __init__(self, cfg: DataConfig, start_step: int = 0,
                 arch: Optional[ArchConfig] = None):
        self.cfg = cfg
        self.arch = arch
        self.step = start_step
        self._q: "queue.Queue" = queue.Queue(maxsize=cfg.prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _make(self, step: int) -> Dict[str, np.ndarray]:
        batch = synth_batch(self.cfg, step)
        if self.arch is not None and self.arch.vision_stub:
            b = batch["tokens"].shape[0]
            rng = _batch_rng(self.cfg, step)
            n_patch = min(64, self.cfg.seq_len // 2)
            batch["patch_embeds"] = rng.standard_normal(
                (b, n_patch, self.arch.d_model)).astype(np.float32)
        if self.arch is not None and self.arch.audio_stub:
            b = batch["tokens"].shape[0]
            rng = _batch_rng(self.cfg, step)
            batch["frame_embeds"] = rng.standard_normal(
                (b, self.cfg.seq_len, self.arch.d_model)
            ).astype(np.float32)
        elif self.arch is not None and self.arch.enc_dec:
            batch["tokens_enc"] = batch["tokens"][:, ::-1].copy()
        return batch

    def _worker(self):
        step = self.step
        while not self._stop.is_set():
            batch = self._make(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        step, batch = self._q.get()
        self.step = step + 1
        return step, batch

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
