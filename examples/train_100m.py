"""End-to-end training driver: ~100M-parameter decoder LM.

Full substrate in play: synthetic data pipeline with prefetch, AdamW with
warmup+cosine, per-layer remat off (CPU), checkpoint/restart every 50
steps, heartbeat monitoring. Resume after interruption just re-runs the
same command.

    PYTHONPATH=src python examples/train_100m.py --steps 300
    PYTHONPATH=src python examples/train_100m.py --steps 20 --tiny  # CI
"""
import argparse
import dataclasses

import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import ModelOptions
from repro.train.optimizer import AdamWConfig
from repro.train.step import TrainConfig
from repro.train.train_loop import LoopConfig, fit


def model_100m() -> ArchConfig:
    """~101M params: 12L d=768 12H d_ff=2048 vocab=32k (GPT-2-small-ish
    with SwiGLU)."""
    return ArchConfig(name="lm_100m", family="dense", n_layers=12,
                      d_model=768, n_heads=12, n_kv_heads=12, d_ff=2048,
                      vocab=32000, tie_embeddings=True)


def model_tiny() -> ArchConfig:
    return dataclasses.replace(model_100m(), name="lm_tiny", n_layers=2,
                               d_model=128, n_heads=4, n_kv_heads=4,
                               d_ff=512, vocab=2048)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_100m")
    ap.add_argument("--tiny", action="store_true",
                    help="2L/128d config for smoke runs")
    args = ap.parse_args()

    cfg = model_tiny() if args.tiny else model_100m()
    print(f"model: {cfg.name}  params={cfg.n_params()/1e6:.1f}M")

    tcfg = TrainConfig(adamw=AdamWConfig(
        lr=args.lr, warmup_steps=max(10, args.steps // 20),
        total_steps=args.steps))
    res = fit(cfg,
              opts=ModelOptions(dtype=jnp.float32, remat=False),
              tcfg=tcfg,
              loop=LoopConfig(steps=args.steps, seq_len=args.seq,
                              global_batch=args.batch, log_every=10,
                              save_every=50, ckpt_dir=args.ckpt_dir))
    print(f"\ndone: {res.steps_done} steps "
          f"(resumed from {res.resumed_from})")
    print(f"loss: {res.losses[0]:.4f} → {res.losses[-1]:.4f}")


if __name__ == "__main__":
    main()
