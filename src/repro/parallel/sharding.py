"""Sharding rules: logical parameter/activation axes → mesh axes.

Megatron-style tensor parallelism over the ``model`` axis:
  * column-parallel: q/k/v projections, MLP up/gate, SSM in_proj
  * row-parallel:    attention out, MLP down, SSM out_proj
  * vocab-parallel:  embedding (vocab dim), LM head (vocab dim)
  * expert-parallel: MoE expert stacks (expert dim over ``model``)
Batch is sharded over ``("pod", "data")`` (or ``("data",)`` single-pod);
long-context decode shards KV-cache SEQUENCE over ``data`` (SP).
ZeRO-1 shards optimizer moments over ``data`` on the first divisible
replicated dim.

Every rule checks divisibility against the mesh and falls back to
replication — 40 heterogeneous (arch x shape) cells must all lower.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        return int(np.prod([mesh.shape[a] for a in axis if a in mesh.shape]))
    return mesh.shape.get(axis, 1) if hasattr(mesh.shape, "get") \
        else mesh.shape[axis]


def _try(dim: int, mesh: Mesh, axis):
    axes = axis if isinstance(axis, (tuple, list)) else (axis,)
    if any(a not in mesh.axis_names for a in axes if a is not None):
        return None                     # unknown axis (e.g. TP disabled)
    return axis if dim % max(1, _axis_size(mesh, axis)) == 0 else None


# leaf-name → (which dim gets 'model',) using negative indices
_COL = {"wq", "wk", "wv", "w_gate", "w_up", "w1", "in_proj",
        "bq", "bk", "bv", "b1", "conv_w", "conv_b", "dt_bias",
        "A_log", "D", "norm_scale"}
_ROW = {"wo", "w_down", "w2", "out_proj"}
_REPL = {"ln", "b2", "final_norm", "enc_norm", "router"}


def param_spec(path: Tuple[str, ...], shape: Tuple[int, ...],
               mesh: Mesh, model_axis: str = "model",
               fsdp_axes=None) -> P:
    name = path[-1]
    nd = len(shape)
    spec = [None] * nd
    if name == "embed":
        spec[0] = _try(shape[0], mesh, model_axis)        # vocab
    elif name == "head":
        spec[-1] = _try(shape[-1], mesh, model_axis)      # vocab
    elif name in _REPL or name.startswith("ln"):
        pass
    elif name in ("w_gate", "w_up", "w_down") and nd >= 4:
        # MoE expert stack (..., E, d, f): experts over `model`
        spec[-3] = _try(shape[-3], mesh, model_axis)
    elif name in _COL:
        spec[-1] = _try(shape[-1], mesh, model_axis)
    elif name in _ROW:
        spec[-2] = _try(shape[-2], mesh, model_axis)
    if fsdp_axes:
        # FSDP/ZeRO-3: shard the LARGEST remaining replicated dim over the
        # data axes (weights gathered per-layer inside the scan)
        cand = [(shape[i], i) for i in range(nd)
                if spec[i] is None
                and shape[i] % _axis_size(mesh, fsdp_axes) == 0
                and shape[i] > 1]
        if cand:
            _, i = max(cand)
            spec[i] = fsdp_axes
    return P(*spec)


def param_specs(params_shape: Any, mesh: Mesh,
                model_axis: str = "model", fsdp_axes=None) -> Any:
    def f(path, leaf):
        names = tuple(getattr(k, "key", getattr(k, "name", str(k)))
                      for k in path)
        return param_spec(names, leaf.shape, mesh, model_axis, fsdp_axes)
    return jax.tree_util.tree_map_with_path(f, params_shape)


def zero1_specs(params_shape: Any, pspecs: Any, mesh: Mesh,
                data_axis="data") -> Any:
    """Optimizer-moment specs: add `data` sharding on the first replicated
    dim that divides (ZeRO-1). Falls back to the param spec."""
    def f(leaf, spec):
        dims = list(spec) + [None] * (len(leaf.shape) - len(spec))
        used = {a for d in dims if d is not None
                for a in (d if isinstance(d, tuple) else (d,))}
        if data_axis in used:          # FSDP already shards over data
            return P(*dims)
        for i, (d, s) in enumerate(zip(leaf.shape, dims)):
            if s is None and d % _axis_size(mesh, data_axis) == 0 and d > 1:
                dims[i] = data_axis
                break
        return P(*dims)
    return jax.tree.map(f, params_shape, pspecs,
                        is_leaf=lambda x: isinstance(x, P))


def batch_specs(batch_shape: Any, mesh: Mesh, batch_axes) -> Any:
    """Shard dim0 (global batch) over the batch mesh axes."""
    def f(leaf):
        spec = [None] * len(leaf.shape)
        if leaf.shape and leaf.shape[0] % _axis_size(mesh, batch_axes) == 0:
            spec[0] = batch_axes
        return P(*spec)
    return jax.tree.map(f, batch_shape)


def cache_specs(cache_shape: Any, mesh: Mesh, batch_axes,
                model_axis: str = "model",
                seq_axis: Optional[str] = None) -> Any:
    """Decode-cache sharding.

    KV leaves are (L, B, S, KH, hd) (or SSM conv (L,B,K,C) / state
    (L,B,H,P,N)). Priority: batch over batch_axes; KV-heads over `model`;
    if batch can't shard (e.g. long_500k B=1) shard SEQUENCE over
    `seq_axis` (sequence parallelism).
    """
    bsz = _axis_size(mesh, batch_axes)

    def f(path, leaf):
        names = tuple(getattr(k, "key", getattr(k, "name", str(k)))
                      for k in path)
        name = names[-1]
        shape = leaf.shape
        nd = len(shape)
        spec = [None] * nd
        if name == "pos":
            return P(_try(shape[0], mesh, batch_axes) if shape else None)
        if nd >= 2:
            spec[1] = _try(shape[1], mesh, batch_axes)    # batch dim

        def seq_spec(dim):
            """Shard a cache SEQUENCE dim: over `model` when KV heads
            can't shard (context parallelism), plus `data` for
            unshardable batch (long-context SP)."""
            axes = []
            if spec[1] is None and seq_axis is not None:
                axes.append(seq_axis)
            if dim % _axis_size(mesh, tuple(axes + [model_axis])) == 0:
                axes.append(model_axis)
            axes = [a for a in axes if dim % _axis_size(mesh, a) == 0]
            if not axes:
                return None
            return tuple(axes) if len(axes) > 1 else axes[0]

        if name in ("k", "v", "cross_k", "cross_v"):      # (L,B,S,KH,hd)
            spec[3] = _try(shape[3], mesh, model_axis)
            if spec[3] is None:
                spec[2] = seq_spec(shape[2])
            elif spec[1] is None and seq_axis is not None:
                spec[2] = _try(shape[2], mesh, seq_axis)
        elif name == "kpos":                              # (L,B,S)
            pass    # small int32; replicated across model (XLA slices it)
        elif name == "state":                             # (L,B,H,P,N)
            spec[2] = _try(shape[2], mesh, model_axis)
        elif name == "conv":                              # (L,B,K,C)
            spec[3] = _try(shape[3], mesh, model_axis)
        return P(*spec)

    return jax.tree_util.tree_map_with_path(f, cache_shape)


def to_shardings(specs: Any, mesh: Mesh) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))
