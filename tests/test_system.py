"""End-to-end behaviour tests for the full system.

1. DistSim models a strategy space and its ranking is consistent with
   the replay oracle (the paper's core claim, §6/Table 2).
2. The real training loop trains a reduced model and the MEASURED step
   time feeds a DistSim 1M1P1D prediction that matches the measured
   step time (model-vs-reality check, the paper's Fig. 3 motivation).
3. Checkpoint/restart mid-run reproduces the uninterrupted loss curve.
"""
import tempfile

import numpy as np
import pytest

from repro.configs.base import get_config, smoke_config
from repro.core import (A40_CLUSTER, AnalyticalProvider, DistSim,
                        MeasuredProvider, Strategy, grid_search)
from repro.train.train_loop import LoopConfig, fit


def test_search_ranking_consistent_with_replay():
    cfg = get_config("bert_exlarge")
    provider = AnalyticalProvider(A40_CLUSTER)
    with pytest.warns(DeprecationWarning, match="grid_search"):
        entries = grid_search(cfg, 16, 16, 512, provider=provider)
    feasible = [e for e in entries if e.feasible]
    assert len(feasible) >= 10
    best, worst = feasible[0], feasible[-1]
    # paper Table 2: best/worst spread is large (7.37x there)
    assert worst.batch_time / best.batch_time > 3.0
    # replay agrees on the ordering of best vs worst
    rb = DistSim(cfg, best.strategy, 16, 512, provider).simulate(seeds=0).result()
    rw = DistSim(cfg, worst.strategy, 16, 512, provider).simulate(seeds=0).result()
    assert rb.batch_time < rw.batch_time


def test_measured_provider_predicts_real_step_time():
    """1M1P1D with MeasuredProvider ≈ real jit step time on this host —
    the no-simulation sanity anchor. Uses a GEMM-dominated reduced
    config (at toy widths, non-GEMM overheads dominate the real step and
    no operator-level profile can see them)."""
    import dataclasses
    cfg = dataclasses.replace(
        smoke_config(get_config("gpt2_345m")), d_model=512, d_ff=2048,
        n_layers=4, vocab=2048, n_heads=8, n_kv_heads=8)
    r = fit(cfg, loop=LoopConfig(steps=6, seq_len=256, global_batch=4,
                                 log_every=100), verbose=False)
    measured = float(np.median(r.step_times[2:]))

    provider = MeasuredProvider()
    sim = DistSim(cfg, Strategy(), global_batch=4, seq=256,
                  provider=provider)
    predicted = sim.simulate().batch_time
    # CPU timing is noisy and the event model is layer-granular; require
    # factor-3 agreement (paper gets <4% with same-hardware profiling)
    assert predicted > 0
    assert 1 / 3 < predicted / measured < 3.0, \
        f"predicted {predicted:.4f}s vs measured {measured:.4f}s"


def test_checkpoint_restart_reproduces_run():
    cfg = smoke_config(get_config("qwen2_1_5b"))
    with tempfile.TemporaryDirectory() as d:
        full = fit(cfg, loop=LoopConfig(steps=12, seq_len=32,
                                        global_batch=2, save_every=100,
                                        ckpt_dir=None), verbose=False)
        part = fit(cfg, loop=LoopConfig(steps=6, seq_len=32,
                                        global_batch=2, save_every=6,
                                        ckpt_dir=d), verbose=False)
        rest = fit(cfg, loop=LoopConfig(steps=12, seq_len=32,
                                        global_batch=2, save_every=6,
                                        ckpt_dir=d), verbose=False)
        assert rest.resumed_from == 6
        np.testing.assert_allclose(rest.losses,
                                   full.losses[6:], rtol=1e-4, atol=1e-4)


def test_profiling_cheaper_than_direct():
    """Table 3: DistSim's profiling cost ≪ direct profiling."""
    cfg = get_config("bert_large")
    provider = AnalyticalProvider(A40_CLUSTER)
    sim = DistSim(cfg, Strategy(mp=2, pp=1, dp=8, microbatches=1),
                  16, 512, provider)
    rep = sim.profiling_report()
    assert rep["relative_scale"] < 0.5
