"""CLI for store maintenance: ``python -m repro.store gc <path>``.

``gc`` compacts a profile store in place — live event shards per
namespace are rewritten into one content-addressed shard, and
stale-``cache_version`` orphans plus corrupt files are deleted. Safe to
run against a store that concurrent writers are appending to: writes
are atomic and content-addressed, so the worst case is a shard written
mid-gc surviving until the next gc.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.store.profile_store import ProfileStore


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.store",
        description="Profile-store maintenance commands.")
    sub = ap.add_subparsers(dest="cmd", required=True)
    gc = sub.add_parser(
        "gc", help="compact event shards, drop stale/corrupt entries")
    gc.add_argument("path", help="store directory")
    gc.add_argument("--json", action="store_true",
                    help="emit the stats dict as JSON")
    args = ap.parse_args(argv)

    if args.cmd == "gc":
        stats = ProfileStore(args.path).gc()
        if args.json:
            print(json.dumps(stats, sort_keys=True))
        else:
            print(f"gc {args.path}: "
                  f"{stats['namespaces']} namespace(s), "
                  f"shards {stats['shards_before']} -> "
                  f"{stats['shards_after']} "
                  f"({stats['events_live']} live events, "
                  f"{stats['events_dropped']} dropped), "
                  f"builds kept {stats['builds_kept']} / "
                  f"dropped {stats['builds_dropped']}")
        return 0
    return 2


if __name__ == "__main__":
    sys.exit(main())
