"""whisper-tiny [audio] — enc-dec transformer backbone, conv frontend stubbed.

4L d_model=384 6H (GQA kv=6 == MHA) d_ff=1536 vocab=51865
[arXiv:2212.04356; unverified]

Shapes: enc-dec; decode shapes drive the decoder with a cached encoder
output. long_500k skipped (full attention).
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="whisper_tiny",
    family="audio",
    n_layers=4,                # 4 encoder + 4 decoder layers
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab=51865,
    qkv_bias=True,
    mlp_gelu=True,
    enc_dec=True,
    audio_stub=True,           # input_specs() provides frame embeddings
    tie_embeddings=True,
    shapes=("train_4k", "prefill_32k", "decode_32k"),
    source="arXiv:2212.04356; unverified",
))
