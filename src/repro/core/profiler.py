"""Event profiling (paper §4.2).

Each unique event is profiled ONCE:

* ``AnalyticalProvider`` — TPU v5e operator-level roofline (the
  "Habitat-style predictor" pathway the paper offers for users without
  profiling hardware). Used for full-size configs and the target cluster.

* ``MeasuredProvider`` — actually executes each compute event's GEMMs with
  jit'd JAX on this host and times them (the analogue of the paper's
  2-node profiling; our container is 1 CPU host). Communication events
  still use the ring model — with 1 host there is no link to measure, the
  same situation the paper solves by extrapolating ≤8-way profiles
  (§4.2: error contribution <2%).

Times are cached per event — repeated strategies re-use profiles, as the
paper notes ("events' time can be stored and reused").
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Iterable

from repro.core.costmodel import (ClusterSpec, V5E_POD, collective_time,
                                  compute_time, hbm_time, p2p_time,
                                  ring_hops, ring_volume_factor)
from repro.core.events import Event


@dataclasses.dataclass
class ProviderStats:
    """Profiling-cost accounting for the search engine.

    ``evaluations`` counts real cost-model evaluations (cache misses) —
    the quantity the paper's unique-event dedup minimizes; ``hits``
    counts reuses of an already-profiled event.
    """
    evaluations: int = 0
    hits: int = 0

    @property
    def lookups(self) -> int:
        return self.evaluations + self.hits

    @property
    def hit_rate(self) -> float:
        return self.hits / self.lookups if self.lookups else 0.0

    def reset(self) -> None:
        self.evaluations = 0
        self.hits = 0


class Provider:
    def __init__(self, cluster: ClusterSpec = V5E_POD):
        self.cluster = cluster
        self._cache: Dict[Event, float] = {}
        self.stats = ProviderStats()
        #: bumped on every cache clear; consumers that bake cached times
        #: into derived structures (EventFlowEngine, validate.BuildCache)
        #: stamp themselves with this and rebuild on mismatch.
        self.cache_version = 0

    def time(self, e: Event) -> float:
        if e not in self._cache:
            self._cache[e] = self._time(e)
            self.stats.evaluations += 1
        else:
            self.stats.hits += 1
        return self._cache[e]

    def cached_time(self, e: Event) -> float:
        """Profiled time of an already-cached event, without touching
        the hit/miss accounting (bookkeeping reads, e.g. the search
        engine's per-candidate profiling-cost sum)."""
        return self._cache[e]

    def clear_cache(self) -> None:
        """Drop profiled event times (stats are kept; reset separately).
        Bumps :attr:`cache_version` so engines holding baked-in means
        from the old cache are invalidated, not silently reused, and
        clears any subclass-derived caches (:meth:`_clear_derived`) so
        re-profiling can't serve measurements from before the clear."""
        self._cache.clear()
        self._clear_derived()
        self.cache_version += 1

    def _clear_derived(self) -> None:
        """Hook for subclasses holding caches derived from profiling
        (e.g. ``MeasuredProvider._group_cache``): called by
        :meth:`clear_cache` so a clear drops EVERYTHING, not just the
        event-time dict."""

    @property
    def cache_size(self) -> int:
        """Number of unique events currently profiled — the public
        accessor for accounting surfaces (``ProfileCache``, stores)
        that previously reached into ``_cache``."""
        return len(self._cache)

    def bare(self) -> "Provider":
        """Copy of this provider with EMPTY event/derived caches and
        fresh stats (same cluster, config and ``cache_version``) — what
        the parallel executor ships to worker processes when a disk
        :class:`repro.store.ProfileStore` carries the warm events
        instead of the pickled parent cache."""
        import copy
        p = copy.copy(self)
        p._cache = {}
        p.stats = ProviderStats()
        return p

    # ---- parallel-sweep shard support (repro.validate.executor) ----
    def cache_snapshot(self) -> Dict[Event, float]:
        """Copy of the profiled-event cache (picklable: Events are
        frozen dataclasses) — what a worker shard sends back."""
        return dict(self._cache)

    def merge_cache(self, entries: Dict[Event, float]) -> int:
        """Merge a shard's profiled events; existing entries win (values
        are identical for a deterministic provider — keeping the
        incumbent makes the merge order-independent). Returns how many
        events were new. Stats are NOT touched: the executor
        reconstructs serial-equivalent accounting from shard lookups."""
        fresh = 0
        for e, t in entries.items():
            if e not in self._cache:
                self._cache[e] = t
                fresh += 1
        return fresh

    def _time(self, e: Event) -> float:
        if e.kind == "compute":
            return self._compute_time(e)
        if e.kind == "collective":
            n = e.n_dev
            if n > 8:
                # paper §4.2: profile 8-way, extrapolate by ring volume.
                # We additionally remove/re-add the per-hop latency term
                # (known from the cluster spec) so the extrapolation is
                # exact — the paper bounds the residual effect at <2%.
                lat = (self.cluster.intra_latency if e.scope == "intra"
                       else self.cluster.inter_latency)
                t8 = (collective_time(e.coll_op, e.nbytes, 8, self.cluster,
                                      e.scope)
                      - ring_hops(e.coll_op, 8) * lat)
                v8 = ring_volume_factor(e.coll_op, 8)
                vn = ring_volume_factor(e.coll_op, n)
                return t8 * vn / v8 + ring_hops(e.coll_op, n) * lat
            return collective_time(e.coll_op, e.nbytes, n, self.cluster,
                                   e.scope)
        if e.kind == "p2p":
            # dPRO's min(SEND, RECV) rule: our model times the transmission
            # itself, which is that minimum by construction.
            return p2p_time(e.nbytes, self.cluster, e.scope)
        if e.kind == "hbm":
            # decode KV-cache / SSM-state read: pure HBM-bandwidth-bound
            return hbm_time(e.nbytes, self.cluster)
        raise ValueError(e.kind)

    def _compute_time(self, e: Event) -> float:
        raise NotImplementedError


class AnalyticalProvider(Provider):
    def _compute_time(self, e: Event) -> float:
        return compute_time(e.gemms, self.cluster.chip)


class MeasuredProvider(Provider):
    """Times real jit'd op groups on this host (reduced configs only).

    An event's GEMMs are executed inside ONE jitted function — the
    operator-level granularity the paper profiles (per-op dispatch
    overheads amortize exactly as in a real fused program). A per-GEMM
    elementwise epilogue approximates the activation/softmax traffic
    between the GEMMs.
    """

    def __init__(self, cluster: ClusterSpec = V5E_POD, reps: int = 3):
        super().__init__(cluster)
        self.reps = reps
        self._group_cache: Dict[tuple, float] = {}

    def _clear_derived(self) -> None:
        # without this, a clear_cache() followed by re-profiling would
        # silently reuse jit timings measured before the clear
        self._group_cache.clear()

    def bare(self) -> "MeasuredProvider":
        p = super().bare()
        p._group_cache = {}
        return p

    def _time_group(self, dims: tuple) -> float:
        if dims in self._group_cache:
            return self._group_cache[dims]
        import jax
        import jax.numpy as jnp

        inputs = [(jnp.ones((m, k), jnp.float32),
                   jnp.ones((k, n), jnp.float32)) for m, n, k in dims]

        def run(args):
            acc = jnp.zeros((), jnp.float32)
            for a, b in args:
                y = a @ b
                y = jax.nn.silu(y)            # epilogue stand-in
                acc = acc + y.sum()
            return acc

        f = jax.jit(run)
        f(inputs).block_until_ready()         # compile
        best = float("inf")
        for _ in range(self.reps):
            t0 = time.perf_counter()
            f(inputs).block_until_ready()
            best = min(best, time.perf_counter() - t0)
        self._group_cache[dims] = best
        return best

    def _compute_time(self, e: Event) -> float:
        dims = tuple((g.m, g.n, g.k) for g in e.gemms)
        return self._time_group(dims) if dims else 0.0


def profile_events(events: Iterable[Event], provider: Provider
                   ) -> Dict[Event, float]:
    return {e: provider.time(e) for e in events}


def profiling_cost(counts: Dict[Event, int], profile: Dict[Event, float]
                   ) -> Dict[str, float]:
    """Table 3: DistSim profiles each unique event once vs direct running
    profiling every instance on every device."""
    unique_t = sum(profile[e] for e in counts)
    direct_t = sum(profile[e] * c for e, c in counts.items())
    return {
        "unique_events": len(counts),
        "total_instances": int(sum(counts.values())),
        "profile_time_s": unique_t,
        "direct_time_s": direct_t,
        "relative_scale": unique_t / direct_t if direct_t else 1.0,
    }
