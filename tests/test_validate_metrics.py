"""Differential/property harness for the array-native validation
metrics: ``compare_batch`` must agree with a naive recompute from
materialized ``Activity`` lists, and ``aggregate`` must satisfy its
algebraic invariants. Hypothesis-based; auto-skips without the
``[test]`` extra (same pattern as the schedule property tests)."""
import dataclasses

import pytest

hp = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")

from repro.configs.base import get_config
from repro.core import A40_CLUSTER, AnalyticalProvider, DistSim, Strategy
from repro.validate import (CellMetrics, aggregate, compare_batch,
                            compare_timelines)

PROVIDER = AnalyticalProvider(A40_CLUSTER)
FIELDS = [f.name for f in dataclasses.fields(CellMetrics)]

finite = st.floats(min_value=0.0, max_value=10.0, allow_nan=False)
metrics_st = st.builds(CellMetrics, **{f: finite for f in FIELDS})


# --------------------------------------------------------------------------
# aggregate() invariants
# --------------------------------------------------------------------------

@hp.given(m=metrics_st)
@hp.settings(max_examples=50, deadline=None)
def test_aggregate_singleton_is_identity(m):
    """aggregate([m]) == m exactly (mean of one, max of one)."""
    assert aggregate([m]) == m


@hp.given(ms=st.lists(metrics_st, min_size=1, max_size=6), data=st.data())
@hp.settings(max_examples=50, deadline=None)
def test_aggregate_permutation_invariant(ms, data):
    """Seed order must not matter (field-wise means re-associate, so
    equality is up to float tolerance; worst_* is an exact max)."""
    perm = data.draw(st.permutations(ms))
    a, b = aggregate(ms), aggregate(perm)
    assert a.worst_batch_time_error == b.worst_batch_time_error
    for f in FIELDS:
        assert getattr(a, f) == pytest.approx(getattr(b, f),
                                              rel=1e-9, abs=1e-12)


@hp.given(ms=st.lists(metrics_st, min_size=1, max_size=6))
@hp.settings(max_examples=50, deadline=None)
def test_aggregate_mean_within_extremes_and_worst_is_max(ms):
    agg = aggregate(ms)
    eps = 1e-9
    for f in FIELDS:
        vals = [getattr(m, f) for m in ms]
        assert min(vals) - eps <= getattr(agg, f) <= max(vals) + eps
    assert agg.worst_batch_time_error == max(m.worst_batch_time_error
                                             for m in ms)


def test_aggregate_empty_is_zero_metrics():
    assert aggregate([]) == CellMetrics()


# --------------------------------------------------------------------------
# array-native compare_batch vs naive materializing recompute
# --------------------------------------------------------------------------

DIFF_CELLS = [
    ("gpt2_345m", Strategy(mp=1, pp=2, dp=2, microbatches=4)),
    ("gpt2_345m", Strategy(mp=2, pp=2, dp=1, microbatches=4,
                           schedule="interleaved", vpp=2)),
    ("gpt2_345m", Strategy(mp=1, pp=2, dp=2, microbatches=4,
                           schedule="pipedream")),
    ("t5_large", Strategy(mp=1, pp=4, dp=1, microbatches=8,
                          schedule="gpipe")),
]


def _batches(arch, strat, seeds=(0, 1, 2), **noise):
    sim = DistSim(get_config(arch), strat,
                  strat.dp * strat.microbatches * 2, 128, PROVIDER)
    noise.setdefault("jitter_sigma", 0.025)
    return (sim, sim.predict_batched(),
            sim.replay_batched(seeds, **noise))


@pytest.mark.parametrize("arch,strat", DIFF_CELLS,
                         ids=lambda v: v if isinstance(v, str)
                         else f"{v.label()}-{v.schedule}")
def test_array_native_equals_naive_recompute(arch, strat):
    """The whole point of the harness: every CellMetrics field computed
    from the batch arrays must equal the naive path that materializes
    both Activity lists and matches (device, name) pairs."""
    sim, pred_b, rep_b = _batches(arch, strat, clock_sigma=1e-4)
    arr = compare_batch(pred_b, rep_b)
    pred_tl = sim.predict().timeline
    assert len(arr) == len(rep_b)
    for i in range(len(rep_b)):
        naive = compare_timelines(pred_tl, rep_b.timeline(i))
        for f in FIELDS:
            assert getattr(arr[i], f) == pytest.approx(
                getattr(naive, f), rel=1e-9, abs=1e-12), (f, i)


def test_compare_batch_rejects_noisy_or_multilane_pred():
    """A noisy (or multi-lane) prediction batch would silently be
    misread as replica-0 unoffset times — must raise, not mislead."""
    _, _, rep_b = _batches("gpt2_345m", DIFF_CELLS[0][1])
    with pytest.raises(ValueError, match="single-lane"):
        compare_batch(rep_b, rep_b)


def test_self_compare_is_exactly_zero():
    """Pred vs itself: every error is 0.0 EXACTLY — the array path may
    not introduce even one ulp of self-disagreement."""
    _, pred_b, _ = _batches("gpt2_345m", DIFF_CELLS[0][1])
    for m in compare_batch(pred_b, pred_b):
        assert m == CellMetrics()


@hp.given(seed=st.integers(0, 2**31 - 1))
@hp.settings(max_examples=15, deadline=None)
def test_batched_metrics_deterministic_and_seed_keyed(seed):
    """Same seed → identical metrics across fresh batches; the metric
    numbers depend only on the seed list, not on batch composition."""
    strat = DIFF_CELLS[0][1]
    _, pred_b, rep_a = _batches("gpt2_345m", strat, seeds=(seed,))
    _, pred_b2, rep_b = _batches("gpt2_345m", strat, seeds=(seed, seed))
    (ma,) = compare_batch(pred_b, rep_a)
    mb = compare_batch(pred_b2, rep_b)
    assert ma == mb[0] == mb[1]
