"""Sweep-scale subsystem: shared build cache, parallel executor, and
the engine/provider caching fixes it exposed.

Acceptance pins (ISSUE 5):
* cached-build sweeps are bit-identical to uncached ones (same
  ``dump()`` JSON, goldens untouched);
* a parallel ``jobs=4`` sweep reproduces the serial report exactly and
  its merged ``ProviderStats.evaluations`` equals the serial sweep's
  unique-event count;
* ``DistSim.engine(positions)`` keys on structural content, not list
  identity;
* ``Provider.clear_cache()`` invalidates engines holding baked-in
  means;
* the >8-way ring extrapolation shares its constants with
  ``costmodel.collective_time`` and stays continuous at the 8->9
  boundary.
"""
import copy

import pytest

from repro.configs.base import get_config
from repro.core import (A40_CLUSTER, AnalyticalProvider, DistSim,
                        EngineBuild, Event, EventFlowEngine, Strategy,
                        collective_time, ring_hops, ring_volume_factor)
from repro.core.events import ComposedEvent
from repro.core.modelgraph import GEMM
from repro.core.hierarchy import build_positions
from repro.validate import (BuildCache, ValidationCell, full_matrix,
                            run_sweep, smoke_matrix)
from repro.validate.report import dump, dumps, load
from repro.validate.sweep import _cell

SEEDS = (0, 1)
MATRIX = smoke_matrix()
SCHEDULES = ("gpipe", "1f1b", "interleaved", "pipedream")


def _family(arch="gpt2_345m", mp=1, pp=2, dp=2, m=4, gb=16, seq=128):
    """One (model, strategy) pair under all four schedules — the
    recurrence the build cache dedups."""
    return [_cell(arch, mp, pp, dp, m, s,
                  vpp=2 if s == "interleaved" else 1, gb=gb, seq=seq)
            for s in SCHEDULES]


# --------------------------------------------------------------------------
# build cache: bit-identity + reuse accounting
# --------------------------------------------------------------------------

def test_cached_sweep_bit_identical_to_uncached():
    a = run_sweep(MATRIX, cluster=A40_CLUSTER, seeds=SEEDS, cache=False)
    b = run_sweep(MATRIX, cluster=A40_CLUSTER, seeds=SEEDS, cache=True)
    assert dumps(a) == dumps(b)
    assert load(dump(a)) == b             # round-trip across the modes


def test_shared_build_engines_bit_identical_per_schedule():
    """The cache's core claim: a schedule only reorders tasks — every
    schedule's engine built from ONE shared (schedule-independent)
    EngineBuild reproduces the from-scratch engine exactly."""
    provider = AnalyticalProvider(A40_CLUSTER)
    cfg = get_config("gpt2_345m")
    for schedule in SCHEDULES:
        vpp = 2 if schedule == "interleaved" else 1
        strat = Strategy(mp=1, pp=2, dp=2, microbatches=4,
                         schedule=schedule, vpp=vpp)
        pos = build_positions(cfg, strat, 2, 128, provider.cluster)
        shared = EngineBuild(pos, strat, provider, with_dp_sync=None)
        cached = EventFlowEngine(pos, strat, provider, build=shared)
        fresh = EventFlowEngine(pos, strat, provider)
        assert cached.run().batch_time == fresh.run().batch_time
        ca = cached.run_batched(SEEDS, jitter_sigma=0.025)
        fr = fresh.run_batched(SEEDS, jitter_sigma=0.025)
        assert list(ca.batch_times) == list(fr.batch_times), schedule


def test_build_cache_shares_across_schedules():
    provider = AnalyticalProvider(A40_CLUSTER)
    cache = BuildCache(provider)
    for cell in _family():
        cache.engine_for(cell)
    # 4 schedules -> 2 positions/builds (vpp=1 shared by three schedules,
    # vpp=2 for interleaved), one engine per schedule
    assert cache.stats.engine_misses == 4
    assert cache.stats.build_misses == 2
    assert cache.stats.build_hits == 2
    assert cache.stats.positions_misses == 2


def test_warm_cache_serves_engines_and_stays_identical():
    provider = AnalyticalProvider(A40_CLUSTER)
    cache = BuildCache(provider)
    a = run_sweep(MATRIX, provider=provider, seeds=SEEDS, cache=cache)
    misses = cache.stats.engine_misses
    b = run_sweep(MATRIX, provider=provider, seeds=SEEDS, cache=cache)
    assert dumps(a) == dumps(b)
    assert cache.stats.engine_misses == misses        # no rebuilds
    assert cache.stats.engine_hits >= len(MATRIX)


def test_build_cache_rejects_foreign_provider():
    cache = BuildCache(AnalyticalProvider(A40_CLUSTER))
    with pytest.raises(ValueError, match="different provider"):
        run_sweep(MATRIX[:1], provider=AnalyticalProvider(A40_CLUSTER),
                  seeds=(0,), cache=cache)


def test_run_batched_memoized_per_seed_set():
    cell = MATRIX[0]
    provider = AnalyticalProvider(A40_CLUSTER)
    cache = BuildCache(provider)
    eng = cache.engine_for(cell)
    assert eng.run_batched(SEEDS, jitter_sigma=0.025) \
        is eng.run_batched(SEEDS, jitter_sigma=0.025)
    # different seeds / sigmas are distinct entries, not collisions
    other = eng.run_batched((2,), jitter_sigma=0.025)
    assert other is not eng.run_batched(SEEDS, jitter_sigma=0.025)


def test_batch_memo_is_bounded():
    """Long-lived cached engines must not pin one TimelineBatch per
    seed set ever requested."""
    provider = AnalyticalProvider(A40_CLUSTER)
    cache = BuildCache(provider)
    eng = cache.engine_for(MATRIX[0])
    for s in range(3 * eng._BATCH_MEMO_MAX):
        eng.run_batched((s,), jitter_sigma=0.025)
    assert len(eng._batch_memo) <= eng._BATCH_MEMO_MAX


def test_engine_rejects_mismatched_build():
    """A build precomputed for other stages must raise, not silently
    simulate the wrong model."""
    provider = AnalyticalProvider(A40_CLUSTER)
    cfg = get_config("gpt2_345m")
    strat = Strategy(mp=1, pp=2, dp=2, microbatches=4)
    pos_a = build_positions(cfg, strat, 2, 128, provider.cluster)
    pos_b = build_positions(cfg, strat, 2, 256, provider.cluster)
    build_b = EngineBuild(pos_b, strat, provider)
    with pytest.raises(ValueError, match="different stages"):
        EventFlowEngine(pos_a, strat, provider, build=build_b)


def test_full_matrix_extended_with_predict_scale_cells():
    cells = full_matrix()
    big = {c.arch for c in cells if c.global_batch == 64}
    assert big == {"gpt_145b", "dbrx_132b", "jamba_v0_1_52b",
                   "qwen2_vl_72b"}
    for c in cells:
        assert c.global_batch % (c.strategy.dp
                                 * c.strategy.microbatches) == 0


# --------------------------------------------------------------------------
# parallel executor: report + stats merge
# --------------------------------------------------------------------------

def test_parallel_jobs4_report_equals_serial():
    serial = run_sweep(MATRIX, cluster=A40_CLUSTER, seeds=SEEDS,
                       cache=False)
    par = run_sweep(MATRIX, cluster=A40_CLUSTER, seeds=SEEDS, jobs=4)
    assert dumps(serial) == dumps(par)


def test_parallel_provider_merge_matches_serial_unique_events():
    """Merged shard caches must count each unique event ONCE — the
    paper's Table 3 accounting — no matter how many workers profiled
    it."""
    sp = AnalyticalProvider(A40_CLUSTER)
    run_sweep(MATRIX, provider=sp, seeds=SEEDS)
    pp_ = AnalyticalProvider(A40_CLUSTER)
    run_sweep(MATRIX, provider=pp_, seeds=SEEDS, jobs=4)
    serial_unique = len(sp.cache_snapshot())
    assert sp.stats.evaluations == serial_unique
    assert pp_.stats.evaluations == serial_unique
    assert set(pp_.cache_snapshot()) == set(sp.cache_snapshot())


def test_parallel_accumulates_shard_cache_stats():
    provider = AnalyticalProvider(A40_CLUSTER)
    cache = BuildCache(provider)
    run_sweep(MATRIX, provider=provider, seeds=(0,), cache=cache, jobs=2)
    assert cache.stats.engine_misses >= len(MATRIX) // 2


# --------------------------------------------------------------------------
# satellite: DistSim.engine(positions) structural identity
# --------------------------------------------------------------------------

def _sim(provider=None):
    return DistSim(get_config("gpt2_345m"),
                   Strategy(mp=1, pp=2, dp=2, microbatches=4),
                   16, 128, provider or AnalyticalProvider(A40_CLUSTER))


def test_engine_reused_for_equal_content_positions():
    sim = _sim()
    pos = sim.positions()
    eng = sim.engine(pos)
    # a fresh, equal-content list must NOT rebuild
    assert sim.engine(copy.deepcopy(pos)) is eng
    assert sim.engine(sim.positions()) is eng


def test_engine_rebuilt_for_mutated_positions():
    """Regression: identity keying returned a stale engine when the
    caller mutated the positions list in place."""
    sim = _sim()
    pos = sim.positions()
    bt = sim.simulate(positions=pos).batch_time
    extra = Event(kind="compute", name="injected",
                  gemms=(GEMM(4096, 4096, 4096),))
    pos[0].fwd = ComposedEvent(pos[0].fwd.name,
                               pos[0].fwd.events + [extra])
    bt_mut = sim.simulate(positions=pos).batch_time
    assert bt_mut != bt                   # not the stale engine
    assert bt_mut > bt                    # stage-0 fwd grew


# --------------------------------------------------------------------------
# satellite: Provider.clear_cache() invalidates engines
# --------------------------------------------------------------------------

class _ScaledProvider(AnalyticalProvider):
    """Times change when ``scale`` changes — only a cache clear may
    expose the new values."""

    def __init__(self, cluster):
        super().__init__(cluster)
        self.scale = 1.0

    def _time(self, e: Event) -> float:
        return self.scale * super()._time(e)


def test_clear_cache_invalidates_default_engine():
    provider = _ScaledProvider(A40_CLUSTER)
    sim = _sim(provider)
    bt = sim.simulate().batch_time
    provider.scale = 2.0
    # without a clear, profiled times (and the engine) legitimately stay
    assert sim.simulate().batch_time == bt
    provider.clear_cache()
    # regression: the engine used to keep its baked-in (stale) means.
    # Exact 2x is NOT expected — optimizer time bypasses the provider.
    bt2 = sim.simulate().batch_time
    assert bt2 != bt
    assert bt < bt2 < 2.0 * bt + 1e-12


def test_clear_cache_invalidates_positions_engine():
    provider = _ScaledProvider(A40_CLUSTER)
    sim = _sim(provider)
    pos = sim.positions()
    bt = sim.simulate(positions=pos).batch_time
    provider.scale = 3.0
    provider.clear_cache()
    bt2 = sim.simulate(positions=pos).batch_time
    assert bt2 != bt
    assert bt < bt2 < 3.0 * bt + 1e-12


def test_clear_cache_invalidates_build_cache():
    provider = _ScaledProvider(A40_CLUSTER)
    cache = BuildCache(provider)
    cell = ValidationCell("gpt2_345m",
                          Strategy(mp=1, pp=2, dp=2, microbatches=4),
                          global_batch=16, seq=128)
    e1 = cache.engine_for(cell)
    provider.scale = 2.0
    provider.clear_cache()
    e2 = cache.engine_for(cell)
    assert e2 is not e1
    assert cache.stats.invalidations == 1
    assert e2.fwd_base[0] == pytest.approx(2.0 * e1.fwd_base[0])


# --------------------------------------------------------------------------
# satellite: profiling_report shares DistSim.microbatch()
# --------------------------------------------------------------------------

def test_profiling_report_uses_microbatch_floor():
    """gb=0 is the degenerate case where the inline recomputation
    (gb // (dp*m) == 0) used to diverge from microbatch()'s max(1, ...)
    floor; both paths must see the same per-microbatch GEMM dims."""
    cfg = get_config("gpt2_345m")
    strat = Strategy(mp=1, pp=2, dp=2, microbatches=4)
    provider = AnalyticalProvider(A40_CLUSTER)
    floor = DistSim(cfg, strat, 0, 128, provider)
    ref = DistSim(cfg, strat, 8, 128, provider)    # micro == 1 exactly
    assert floor.microbatch() == ref.microbatch() == 1
    a, b = floor.profiling_report(), ref.profiling_report()
    assert a["unique_events"] == b["unique_events"]
    assert a["profile_time_s"] == pytest.approx(b["profile_time_s"])


# --------------------------------------------------------------------------
# satellite: ring extrapolation helpers + continuity
# --------------------------------------------------------------------------

RING_OPS = ("all_reduce", "all_gather", "reduce_scatter", "all_to_all")


def test_ring_helpers_reject_unknown_op():
    with pytest.raises(ValueError):
        ring_hops("broadcast", 8)
    with pytest.raises(ValueError):
        ring_volume_factor("broadcast", 8)


@pytest.mark.parametrize("op", RING_OPS)
@pytest.mark.parametrize("scope", ("intra", "inter"))
def test_extrapolation_matches_direct_formula(op, scope):
    """With the hop-latency term removed/re-added via the shared
    helpers, the >8-way extrapolation is exact, not just <2% off."""
    provider = AnalyticalProvider(A40_CLUSTER)
    for n in (9, 12, 16, 64):
        e = Event(kind="collective", name=f"{op}:{n}", coll_op=op,
                  nbytes=4e6, n_dev=n, scope=scope)
        assert provider.time(e) == pytest.approx(
            collective_time(op, 4e6, n, A40_CLUSTER, scope), rel=1e-12)


@pytest.mark.parametrize("op", RING_OPS)
def test_extrapolation_continuous_at_nine(op):
    """Continuity: the first extrapolated point (n=9) follows the
    direct formula's trend at n=8 — no jump at the profile boundary."""
    provider = AnalyticalProvider(A40_CLUSTER)

    def t(n):
        return provider.time(Event(kind="collective", name=f"c:{n}",
                                   coll_op=op, nbytes=4e6, n_dev=n))
    step_78 = t(8) - t(7)
    step_89 = t(9) - t(8)
    assert t(9) > t(8)
    # the ring's per-device volume increments shrink with n, so the
    # 8->9 step must stay within the 7->8 trend
    assert step_89 <= step_78 + 1e-12
