"""Per-cell conformance metrics (paper §5).

A :class:`CellMetrics` is one predict-vs-replay comparison reduced to
the paper's evaluation numbers: batch-time error (§5.2, target <4%),
per-device activity-time error (§5.3, target <5%), per-stage timestamp
error (§5.4), plus duration/utilization/bubble deltas that localize a
regression (schedule drift vs event-time drift). Multi-seed replays
aggregate field-wise (mean), with the worst seed's batch-time error
kept so a single bad draw can't hide in the average.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Sequence

from repro.core.serde import dataclass_from_dict
from repro.core.timeline import Timeline, error_summary


@dataclasses.dataclass(frozen=True)
class CellMetrics:
    batch_time_error: float = 0.0
    activity_error_mean: float = 0.0
    activity_error_max: float = 0.0
    stage_error_mean: float = 0.0
    stage_error_max: float = 0.0
    duration_error_mean: float = 0.0
    duration_error_max: float = 0.0
    utilization_delta_max: float = 0.0
    bubble_delta: float = 0.0
    worst_batch_time_error: float = 0.0

    def to_dict(self) -> Dict[str, float]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, float]) -> "CellMetrics":
        return dataclass_from_dict(cls, d)


def compare_timelines(pred: Timeline, actual: Timeline) -> CellMetrics:
    """Metrics for one (prediction, replay) pair."""
    s = error_summary(pred, actual)
    return CellMetrics(worst_batch_time_error=s["batch_time_error"], **s)


def aggregate(per_seed: Sequence[CellMetrics]) -> CellMetrics:
    """Field-wise mean over seeds; ``worst_batch_time_error`` takes the
    max so the aggregate still exposes the worst single replay."""
    if not per_seed:
        return CellMetrics()
    n = len(per_seed)
    fields = [f.name for f in dataclasses.fields(CellMetrics)]
    means = {f: sum(getattr(m, f) for m in per_seed) / n for f in fields}
    means["worst_batch_time_error"] = max(m.worst_batch_time_error
                                          for m in per_seed)
    return CellMetrics(**means)
