"""Roofline HLO analyzer: trip counts, collective traffic, flops."""
import jax
import jax.numpy as jnp
import pytest

from repro.core.roofline import collective_bytes, hlo_stats


def test_scan_trip_count_flops():
    """jit(scan of 10 matmuls) must report 10x one matmul's flops —
    the exact case where XLA's cost_analysis reports 1x."""
    def one(x, w):
        return x @ w

    def scanned(x, ws):
        def body(c, w):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, ws)
        return y

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w1 = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w10 = jax.ShapeDtypeStruct((10, 128, 128), jnp.float32)
    f1 = hlo_stats(jax.jit(one).lower(x, w1).compile().as_text())["flops"]
    f10 = hlo_stats(jax.jit(scanned).lower(x, w10).compile().as_text()
                    )["flops"]
    expected = 2 * 128 ** 3
    assert abs(f1 - expected) / expected < 0.05
    assert abs(f10 - 10 * expected) / (10 * expected) < 0.05


def test_collective_ring_traffic_parsing():
    hlo = """
HloModule test

ENTRY %main (a: f32[1024,256]) -> f32[1024,256] {
  %a = f32[1024,256] parameter(0)
  %ar = f32[1024,256] all-reduce(%a), replica_groups=[4,8]<=[32]T(0), to_apply=%sum
  ROOT %ag = f32[1024,256] all-gather(%ar), replica_groups={{0,1,2,3}}, dimensions={0}
}
"""
    out = collective_bytes(hlo)
    r = 1024 * 256 * 4
    assert abs(out["all-reduce"] - 2 * r * 7 / 8) < 1
    assert abs(out["all-gather"] - r * 3 / 4) < 1
    assert out["count"] == 2


def test_async_pairs_counted_once():
    hlo = """
HloModule t

ENTRY %main (a: f32[64]) -> f32[64] {
  %a = f32[64] parameter(0)
  %s = f32[64] all-gather-start(%a), replica_groups={{0,1}}, dimensions={0}
  ROOT %d = f32[64] all-gather-done(%s)
}
"""
    out = collective_bytes(hlo)
    assert out["count"] == 1


@pytest.mark.xfail(
    reason="seed-known: uses jax.set_mesh, absent in jax<=0.4.x",
    strict=False)
def test_while_body_collectives_multiplied():
    """Collectives inside a lax.scan body scale with trip count."""
    mesh = jax.make_mesh((1,), ("d",))
    from jax.sharding import PartitionSpec as P

    def f(x, ws):
        def body(c, w):
            y = c @ w
            return jax.lax.with_sharding_constraint(y, P()), None
        out, _ = jax.lax.scan(body, x, ws)
        return out.sum()

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((7, 64, 64), jnp.float32)
    with jax.set_mesh(mesh):
        txt = jax.jit(f).lower(x, ws).compile().as_text()
    st = hlo_stats(txt)
    expected = 7 * 2 * 64 ** 3
    assert abs(st["flops"] - expected) / expected < 0.05


def test_dtype_sizes():
    hlo = """
HloModule t

ENTRY %main (a: bf16[100]) -> bf16[100] {
  %a = bf16[100] parameter(0)
  ROOT %ar = bf16[100] all-reduce(%a), replica_groups={{0,1}}, to_apply=%s
}
"""
    out = collective_bytes(hlo)
    assert abs(out["all-reduce"] - 2 * 200 * 0.5) < 1   # 2·R·(N−1)/N, N=2
