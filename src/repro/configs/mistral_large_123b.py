"""mistral-large-123b [dense].

88L d_model=12288 96H (GQA kv=8) d_ff=28672 vocab=32768
[hf:mistralai/Mistral-Large-Instruct-2407; unverified]

long_500k skipped: pure full attention (see DESIGN.md §4).
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="mistral_large_123b",
    family="dense",
    n_layers=88,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    d_ff=28672,
    vocab=32768,
    rope_theta=1e6,
    shapes=("train_4k", "prefill_32k", "decode_32k"),
    source="hf:mistralai/Mistral-Large-Instruct-2407; unverified",
))
