"""Candidate pruning for strategy search.

Two sound filters applied before full timeline construction (Proteus /
DistIR-style: make the simulator cheap enough to sweep big grids):

1. **Memory feasibility** — the rough per-device HBM model (params /
   (mp*pp) with weights + grads + fp32 Adam state, plus live
   activations of one microbatch). Infeasible candidates are reported
   but never simulated.

2. **Work lower bound** — the busiest pipeline device must serially
   execute every microbatch's fwd+bwd composed events; no schedule,
   overlap, or comm pattern can beat that. If the bound already exceeds
   the best fully-simulated batch time, the candidate is dominated and
   timeline construction is skipped. The bound reuses the shared event
   profile, so pruning costs at most a few cache lookups.
"""
from __future__ import annotations

from typing import List

from repro.configs.base import ArchConfig
from repro.core.events import Stage, Strategy
from repro.core.modelgraph import kv_cache_bytes
from repro.core.profiler import Provider
from repro.core.scenario import TRAIN, Scenario

#: fraction of HBM usable for model state + activations
HBM_BUDGET = 0.92


def estimate_memory(cfg: ArchConfig, strat: Strategy, microbatch: int,
                    seq: int, scenario: Scenario = TRAIN) -> float:
    """Per-device bytes, scenario-aware.

    Train: params/mp/pp x (w + grad + 2 adam fp32) + live activations
    of one microbatch. Serving: bf16 weights only (no grads/optimizer)
    + live activations; decode additionally holds its share of the KV
    cache / SSM state (``microbatch`` = concurrent slots per replica,
    sharded over mp*pp like the layers that own it).
    """
    n = cfg.n_params()
    if scenario.is_train:
        state_bytes = n / (strat.mp * strat.pp) * (2 + 2 + 8 / (
            strat.dp if strat.zero1 else 1))
    else:
        state_bytes = n / (strat.mp * strat.pp) * 2        # bf16 weights
        if scenario.kind == "decode":
            state_bytes += kv_cache_bytes(
                cfg, microbatch, scenario.kv_len(seq)) / (strat.mp
                                                          * strat.pp)
    eff_seq = 1 if scenario.kind == "decode" else seq
    act = 2.0 * microbatch * eff_seq * cfg.d_model * 4   # rough live acts
    return state_bytes + act


def memory_feasible(cfg: ArchConfig, strat: Strategy, microbatch: int,
                    seq: int, hbm_bytes: float,
                    scenario: Scenario = TRAIN) -> bool:
    return estimate_memory(cfg, strat, microbatch, seq, scenario) \
        < hbm_bytes * HBM_BUDGET


def hbm_headroom(cfg: ArchConfig, strat: Strategy, microbatch: int,
                 seq: int, hbm_bytes: float,
                 scenario: Scenario = TRAIN) -> float:
    """Free HBM after model state + activations — one of the Pareto
    objectives (more headroom = larger future batches / longer seqs;
    for decode, more concurrent slots / longer contexts)."""
    return hbm_bytes * HBM_BUDGET - estimate_memory(cfg, strat,
                                                    microbatch, seq,
                                                    scenario)


def work_lower_bound(positions: List[Stage], strat: Strategy,
                     provider: Provider) -> float:
    """Sound batch-time lower bound from per-device serial work."""
    pp = strat.pp
    per_dev = [0.0] * pp
    for st in positions:
        per_dev[st.index % pp] += (
            sum(provider.time(e) for e in st.fwd.events)
            + sum(provider.time(e) for e in st.bwd.events))
    return strat.microbatches * max(per_dev, default=0.0)
