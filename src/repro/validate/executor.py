"""Parallel sweep executor (tentpole of the sweep-scale subsystem).

The accuracy sweep is embarrassingly parallel per cell: cells only
interact through the shared profiling provider (the paper's
unique-event dedup) and the shared build cache. ``run_parallel`` fans
cells out over worker processes, each with its OWN provider shard
(seeded with the parent's already-profiled events) and its own
:class:`~repro.validate.build_cache.BuildCache`, then merges the
shards back deterministically:

* **results** are reassembled in cell order, so the merged
  ``SweepResult`` — and its ``report.dump()`` JSON — is bit-identical
  to the serial sweep's (every per-cell number is a deterministic
  function of the cell + provider, not of scheduling);
* **event caches** merge by set-union with incumbent-wins semantics
  (values are identical across shards for a deterministic provider),
  so ``ProviderStats.evaluations`` afterwards equals the serial
  sweep's unique-event count: an event profiled by two shards still
  counts ONCE, exactly as the paper's Table 3 accounting requires;
* **hits** absorb the remaining shard lookups, so ``lookups`` stays
  the true number of provider queries performed.

Workers are spawned via fork where available (cheap, no re-import);
the payloads (cells, provider, thresholds) are all plain picklable
dataclasses, so spawn-only platforms work too.
"""
from __future__ import annotations

import multiprocessing
import sys
from concurrent.futures import ProcessPoolExecutor
from typing import List, Optional, Sequence, Tuple

from repro.core.profiler import Provider
from repro.validate.build_cache import BuildCache, BuildCacheStats
from repro.validate.sweep import (CellResult, Thresholds, ValidationCell,
                                  run_cell)


def _mp_context():
    """fork is the cheap path (no re-import in workers), but forking a
    process that already initialized JAX's thread pools can deadlock —
    fall back to spawn whenever jax is loaded (e.g. under the full
    test session). The sweep itself is numpy-only either way."""
    methods = multiprocessing.get_all_start_methods()
    if "fork" in methods and "jax" not in sys.modules:
        return multiprocessing.get_context("fork")
    return multiprocessing.get_context("spawn")


def _chunk(n: int, jobs: int) -> List[range]:
    """Contiguous near-even index chunks. Contiguity matters: the full
    matrix lists each (model, strategy) pair's four schedules
    consecutively, so keeping neighbors together maximizes each
    worker's build-cache hit rate."""
    base, extra = divmod(n, jobs)
    out, start = [], 0
    for w in range(jobs):
        size = base + (1 if w < extra else 0)
        out.append(range(start, start + size))
        start += size
    return [r for r in out if len(r)]


def _run_shard(payload) -> Tuple[List[Tuple[int, CellResult]], dict, int,
                                 BuildCacheStats]:
    """One worker: run a slice of cells against a private provider
    shard; report results, the shard's newly-profiled events, its
    lookup count and its build-cache accounting.

    With a ``store_path`` the shard provider arrives BARE (no pickled
    parent event cache): the worker opens the shared disk store, loads
    the persisted events (the parent flushed its own before spawning)
    and serves/persists engine builds through it — closing the old
    warm-cache gap where a passed ``BuildCache`` was neither consulted
    nor warmed under ``jobs > 1``."""
    (provider, indexed_cells, seeds, thresholds, jitter_sigma, batched,
     use_cache, store_path) = payload
    provider.stats.reset()
    store = None
    if store_path is not None:
        from repro.store import PersistentBuildCache, open_store
        store = open_store(store_path)
        if use_cache:
            cache = PersistentBuildCache(provider, store)  # loads events
        else:
            cache = None
            store.load_events(provider)
    else:
        cache = BuildCache(provider) if use_cache else None
    known = set(provider.cache_snapshot())
    results = [(idx, run_cell(cell, provider, seeds, thresholds,
                              jitter_sigma, batched=batched, cache=cache))
               for idx, cell in indexed_cells]
    delta = {e: t for e, t in provider.cache_snapshot().items()
             if e not in known}
    if store is not None:
        if cache is not None:
            cache.flush()
        elif delta:
            store.save_events(provider, delta)
    cache_stats = cache.stats if cache is not None else BuildCacheStats()
    return results, delta, provider.stats.lookups, cache_stats


def run_parallel(cells: Sequence[ValidationCell], provider: Provider,
                 seeds: Sequence[int] = (0, 1, 2),
                 thresholds: Optional[Thresholds] = None,
                 jitter_sigma: float = 0.025, jobs: int = 2,
                 batched: bool = True, use_cache: bool = True,
                 cache_stats: Optional[BuildCacheStats] = None,
                 store=None) -> List[CellResult]:
    """Evaluate ``cells`` across ``jobs`` worker processes.

    Mutates ``provider`` exactly as the serial sweep would: its event
    cache gains the union of all shards' profiled events and its stats
    advance by the serial-equivalent (evaluations += newly unique,
    hits += remaining lookups). Pass ``cache_stats`` to additionally
    accumulate the shards' build-cache accounting.

    ``store`` (a :class:`repro.store.ProfileStore` or path) switches
    the shard hand-off to disk: the parent flushes its profiled events
    once, ships BARE providers (no pickled event cache per shard), and
    each worker opens the store for warm events + persisted engine
    builds, flushing its own additions back. Results and accounting
    stay identical; the store — not a per-run in-memory cache — is
    what survives for the next process.
    """
    thresholds = thresholds or Thresholds()
    cells = list(cells)
    jobs = max(1, min(int(jobs), len(cells) or 1))
    if store is not None:
        from repro.store import PersistentBuildCache, open_store
        store = open_store(store)
    if jobs == 1:
        if store is not None and use_cache:
            cache = PersistentBuildCache(provider, store)
        elif store is not None:
            cache = None
            store.load_events(provider)
        else:
            cache = BuildCache(provider) if use_cache else None
        known = set(provider.cache_snapshot()) if store is not None \
            else None
        out = [run_cell(c, provider, seeds, thresholds, jitter_sigma,
                        batched=batched, cache=cache)
               for c in cells]
        if store is not None:
            if cache is not None:
                cache.flush()
            else:
                delta = {e: t
                         for e, t in provider.cache_snapshot().items()
                         if e not in known}
                if delta:
                    store.save_events(provider, delta)
        if cache is not None and cache_stats is not None:
            cache_stats.merge(cache.stats)
        return out

    if store is not None:
        # disk is the shard hand-off: parent's events go through the
        # store once, workers start from a BARE provider
        store.load_events(provider)
        store.save_events(provider)
        ship = provider.bare()
        store_path = store.path
    else:
        ship = provider
        store_path = None
    payloads = []
    for idx_range in _chunk(len(cells), jobs):
        indexed = [(i, cells[i]) for i in idx_range]
        payloads.append((ship, indexed, tuple(seeds), thresholds,
                         jitter_sigma, batched, use_cache, store_path))

    with ProcessPoolExecutor(max_workers=len(payloads),
                             mp_context=_mp_context()) as pool:
        shards = list(pool.map(_run_shard, payloads))

    results: List[Optional[CellResult]] = [None] * len(cells)
    new_events = 0
    total_lookups = 0
    for shard_results, delta, lookups, shard_cache_stats in shards:
        for idx, res in shard_results:
            results[idx] = res
        new_events += provider.merge_cache(delta)
        total_lookups += lookups
        if cache_stats is not None:
            cache_stats.merge(shard_cache_stats)
    # serial-equivalent accounting: each unique event counts once no
    # matter how many shards profiled it; everything else was a reuse
    provider.stats.evaluations += new_events
    provider.stats.hits += total_lookups - new_events
    if store is not None:
        # absorb events persisted by workers (or concurrent writers)
        # that no shard delta carried — merge_cache leaves stats alone
        store.load_events(provider)
    assert all(r is not None for r in results)
    return results
