"""Per-architecture smoke tests (deliverable f).

Each assigned architecture gets a REDUCED same-family config and runs
one forward + one train step on CPU, asserting output shapes and
finiteness. Full configs are exercised only via the dry-run.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import (SHAPES, ShapeConfig, get_config,
                                list_archs, smoke_config)
from repro.models.api import build_model, input_specs, make_batch
from repro.models.layers import ModelOptions
from repro.train import optimizer as opt
from repro.train.step import TrainConfig, make_train_step

OPTS = ModelOptions(dtype=jnp.float32, remat=False)
SHAPE = ShapeConfig("smoke", 64, 2, "train")
ARCHS = list_archs(assigned_only=True)


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_loss(arch, key):
    cfg = smoke_config(get_config(arch))
    api = build_model(cfg, OPTS)
    params = api.init(key)
    batch = make_batch(cfg, SHAPE, key, OPTS)
    logits = api.forward(params, batch)
    assert logits.ndim == 3 and logits.shape[0] == 2
    assert logits.shape[-1] == cfg.vocab
    assert bool(jnp.all(jnp.isfinite(logits)))
    loss = api.loss(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss))
    # random init ⇒ loss ≈ ln(vocab)
    assert abs(float(loss) - jnp.log(cfg.vocab)) < 1.0


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step(arch, key):
    cfg = smoke_config(get_config(arch))
    step = jax.jit(make_train_step(cfg, OPTS, TrainConfig()))
    api = build_model(cfg, OPTS)
    params = api.init(key)
    state = opt.init(params)
    batch = make_batch(cfg, SHAPE, key, OPTS)
    new_params, new_state, metrics = step(params, state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    assert int(new_state.step) == 1
    # parameters actually moved
    moved = any(
        float(jnp.max(jnp.abs(a.astype(jnp.float32)
                              - b.astype(jnp.float32)))) > 0
        for a, b in zip(jax.tree.leaves(params),
                        jax.tree.leaves(new_params)))
    assert moved


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step(arch, key):
    cfg = smoke_config(get_config(arch))
    api = build_model(cfg, OPTS)
    params = api.init(key)
    cache = api.init_cache(2, 32)
    tok = jnp.array([[3], [7]], jnp.int32)
    logits, new_cache = api.decode_step(params, cache, {"tokens": tok})
    assert logits.shape == (2, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert int(new_cache["pos"][0]) == 1


@pytest.mark.parametrize("arch", ARCHS)
def test_input_specs_cover_all_shapes(arch):
    cfg = get_config(arch)
    for name in cfg.shapes:
        shape = SHAPES[name]
        specs = input_specs(cfg, shape)
        leaves = jax.tree.leaves(specs)
        assert leaves, f"{arch}/{name} produced no input specs"
        for l in leaves:
            assert isinstance(l, jax.ShapeDtypeStruct)


def test_long_500k_only_subquadratic():
    for arch in ARCHS:
        cfg = get_config(arch)
        if "long_500k" in cfg.shapes:
            ok = (cfg.is_attention_free or cfg.hybrid_period
                  or cfg.sliding_window)
            assert ok, f"{arch} claims long_500k but is full attention"
