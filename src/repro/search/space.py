"""Strategy search space (paper §6).

Enumerates hybrid-parallel candidates (MP, PP, DP, microbatches,
schedule) for a fixed device count — the grid the paper sweeps in
Fig. 12 / Table 2. The enumeration order is deterministic and shared by
the cached engine and the naive baseline, so their rankings are
directly comparable.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

from repro.core.events import Strategy


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One point of the search grid: a strategy plus its per-device
    microbatch size (derived from the global batch)."""
    strategy: Strategy
    microbatch: int

    def label(self) -> str:
        return (f"{self.strategy.label()}@m{self.strategy.microbatches}"
                f":{self.strategy.schedule}")


def powers_of_two(n: int) -> List[int]:
    out, p = [], 1
    while p <= n:
        out.append(p)
        p *= 2
    return out


def enumerate_candidates(n_devices: int, global_batch: int,
                         microbatches: Optional[Sequence[int]] = None,
                         schedules: Sequence[str] = ("1f1b",),
                         zero1_options: Sequence[bool] = (False,)
                         ) -> List[Candidate]:
    """All (mp, pp, dp, m, schedule[, zero1]) combos with power-of-two
    degrees whose product is exactly ``n_devices`` and whose microbatch
    count divides the per-replica batch."""
    out: List[Candidate] = []
    for mp in powers_of_two(n_devices):
        for pp in powers_of_two(n_devices // mp):
            dp = n_devices // (mp * pp)
            if mp * pp * dp != n_devices or global_batch % dp:
                continue
            per_replica = global_batch // dp
            mb_opts = microbatches or sorted({
                m for m in powers_of_two(per_replica)
                if m >= min(pp, per_replica)})
            for m in mb_opts:
                if per_replica % m:
                    continue
                for sch in schedules:
                    for z1 in zero1_options:
                        strat = Strategy(mp=mp, pp=pp, dp=dp,
                                         microbatches=m, schedule=sch,
                                         zero1=z1)
                        out.append(Candidate(strat, per_replica // m))
    return out
