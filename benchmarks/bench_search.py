"""Strategy-search throughput benchmark (candidates/sec).

Compares three engine configurations on the same grid:

* ``naive``  — per-candidate profiling, no pruning (the seed
  ``grid_search`` behavior);
* ``cached`` — shared profile cache, no pruning;
* ``pruned`` — shared cache + memory filter + work-lower-bound pruning
  (the production path).

``--megabatch`` adds a warm-cache A/B: the same SearchEngine grid run
per-cell vs through the mega-batch array program, asserting identical
rankings and bit-identical batch times, and gating (in ``--smoke``)
on a >=10x evals/sec speedup.

Prints ``name,us_per_call,derived`` CSV like ``benchmarks/run.py``.

    PYTHONPATH=src python benchmarks/bench_search.py [--smoke] [--megabatch]
"""
from __future__ import annotations

import argparse
import sys
import time

from repro.configs.base import get_config, smoke_config
from repro.core import get_cluster
from repro.search import SearchEngine, format_report, search_report


def run_mode(name, cfg, clusters, devices, gb, seq, grid, share_cache,
             prune):
    eng = SearchEngine(cfg, clusters=clusters, share_cache=share_cache,
                       prune=prune, check_memory=True)
    res = eng.search(devices, gb, seq, **grid)
    st = res.stats
    best = res.best()
    row = (f"search/{name}", st.wall_time_s * 1e6,
           f"cand/s={st.candidates_per_s:.1f} "
           f"evals={st.provider_evaluations} "
           f"simulated={st.evaluated} pruned={st.pruned_bound} "
           f"oom={st.pruned_memory} "
           f"best={best.strategy.label() if best else 'n/a'}")
    return res, row


def bench_megabatch(cfg, clusters, devices, gb, seq, grid, smoke,
                    repeats=5):
    """Warm-cache repeat-search A/B: per-cell vs mega-batch lane.

    Both engines share a BuildCache-backed ProfileCache, are warmed
    once, then timed best-of-N on the repeat search — isolating the
    predict evaluation (the part the array program vectorizes) from
    one-time profiling. Returns CSV rows; exits nonzero on a ranking
    or batch-time mismatch, or (in smoke mode) a <10x speedup.
    """
    percell = SearchEngine(cfg, clusters=clusters, share_cache=True,
                           prune=False, check_memory=True,
                           megabatch=False)
    mega = SearchEngine(cfg, clusters=clusters, share_cache=True,
                        prune=False, check_memory=True, megabatch=True)
    r_cell = percell.search(devices, gb, seq, **grid)   # warm caches
    r_mega = mega.search(devices, gb, seq, **grid)

    same_rank = ([e.strategy for e in r_cell.entries]
                 == [e.strategy for e in r_mega.entries])
    same_times = all(a.batch_time == b.batch_time
                     for a, b in zip(r_cell.entries, r_mega.entries))
    if not (same_rank and same_times):
        print("search/ERROR,0,megabatch ranking/batch-time mismatch",
              file=sys.stderr)
        sys.exit(1)

    def best_of(engine):
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            engine.search(devices, gb, seq, **grid)
            best = min(best, time.perf_counter() - t0)
        return best

    t_cell, t_mega = best_of(percell), best_of(mega)
    evaluated = r_mega.stats.evaluated
    speedup = t_cell / t_mega if t_mega else 0.0
    rows = [
        ("search/megabatch_percell", t_cell * 1e6,
         f"evals/s={evaluated / t_cell:.1f} lanes=0"),
        ("search/megabatch_vectorized", t_mega * 1e6,
         f"evals/s={evaluated / t_mega:.1f} "
         f"lanes={r_mega.stats.megabatch_lanes} "
         f"backend=auto bitwise_identical=True"),
        ("search/megabatch_speedup", 0.0,
         f"warm_vectorized_vs_percell={speedup:.2f}x"),
    ]
    if smoke and speedup < 10.0:
        rows.append(("search/ERROR", 0.0,
                     f"megabatch speedup {speedup:.2f}x < 10x gate"))
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived}")
        sys.exit(1)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config + small grid (CI job)")
    ap.add_argument("--megabatch", action="store_true",
                    help="add the warm per-cell vs mega-batch A/B "
                         "(>=10x gate in smoke mode)")
    ap.add_argument("--arch", default="bert_exlarge")
    ap.add_argument("--devices", type=int, default=64)
    ap.add_argument("--global-batch", type=int, default=64)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--clusters", default="a40-cluster",
                    help="comma-separated ClusterSpec names")
    ap.add_argument("--report", action="store_true",
                    help="print the full search report for 'pruned'")
    args = ap.parse_args()

    if args.smoke:
        cfg = smoke_config(get_config("gpt2_345m"))
        devices, gb, seq = 16, 16, 128
        grid = dict(microbatches=(1, 2, 4, 8),
                    schedules=("1f1b", "gpipe"))
    else:
        cfg = get_config(args.arch)
        devices, gb, seq = args.devices, args.global_batch, args.seq
        grid = dict(schedules=("1f1b", "gpipe", "interleaved"))
    clusters = [get_cluster(n) for n in args.clusters.split(",")]

    print("name,us_per_call,derived")
    rows = []
    naive_res, row = run_mode("naive", cfg, clusters, devices, gb, seq,
                              grid, share_cache=False, prune=False)
    rows.append(row)
    cached_res, row = run_mode("cached", cfg, clusters, devices, gb, seq,
                               grid, share_cache=True, prune=False)
    rows.append(row)
    pruned_res, row = run_mode("pruned", cfg, clusters, devices, gb, seq,
                               grid, share_cache=True, prune=True)
    rows.append(row)

    ne = naive_res.stats.provider_evaluations
    ce = cached_res.stats.provider_evaluations
    rows.append(("search/eval_reduction", 0.0,
                 f"naive/cached={ne / ce if ce else 0.0:.2f}x"))
    speed = (pruned_res.stats.candidates_per_s
             / naive_res.stats.candidates_per_s
             if naive_res.stats.candidates_per_s else 0.0)
    rows.append(("search/speedup", 0.0,
                 f"pruned_vs_naive={speed:.2f}x"))
    if args.megabatch:
        rows.extend(bench_megabatch(cfg, clusters, devices, gb, seq,
                                    grid, args.smoke))
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")

    ok = (naive_res.best() and pruned_res.best()
          and naive_res.best().strategy == pruned_res.best().strategy)
    if not ok:
        print("search/ERROR,0,best strategy mismatch", file=sys.stderr)
        sys.exit(1)
    if args.report:
        print(file=sys.stderr)
        print(format_report(search_report(pruned_res)), file=sys.stderr)


if __name__ == "__main__":
    main()
