"""qwen3-moe-30b-a3b [moe] — 128 experts, top-8, fine-grained d_ff.

48L d_model=2048 32H (GQA kv=4) d_ff=768(per-expert) vocab=151936, MoE 128e top-8
[hf:Qwen/Qwen3-30B-A3B; hf]

long_500k skipped: full attention (see DESIGN.md §4).
"""
from repro.configs.base import ArchConfig, MoEConfig, register

CONFIG = register(ArchConfig(
    name="qwen3_moe_30b_a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_ff=768,                   # per-expert hidden (fine-grained)
    vocab=151936,
    rope_theta=1e6,
    moe=MoEConfig(n_experts=128, top_k=8, d_ff_expert=768),
    shapes=("train_4k", "prefill_32k", "decode_32k"),
    source="hf:Qwen/Qwen3-30B-A3B; hf",
))
