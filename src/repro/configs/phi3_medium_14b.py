"""phi3-medium-14b [dense] — RoPE SwiGLU GQA.

40L d_model=5120 40H (GQA kv=10) d_ff=17920 vocab=100352
[arXiv:2404.14219; unverified]

long_500k skipped: pure full attention (see DESIGN.md §4).
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="phi3_medium_14b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=10,
    d_ff=17920,
    vocab=100352,
    rope_theta=1e4,
    shapes=("train_4k", "prefill_32k", "decode_32k"),
    source="arXiv:2404.14219; unverified",
))
