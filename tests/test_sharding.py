"""Sharding rules: divisibility safety, FSDP/ZeRO-1 invariants."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import get_config, smoke_config
from repro.models.api import build_model
from repro.models.layers import ModelOptions
from repro.parallel import sharding
from repro.train import optimizer as optlib

MESH = jax.make_mesh((1, 1), ("data", "model"))


def _axis_names(spec):
    for s in spec:
        if s is None:
            continue
        for a in (s if isinstance(s, tuple) else (s,)):
            yield a


def _specs_for(arch, fsdp=None, mesh=MESH):
    cfg = get_config(arch)
    opts = ModelOptions(dtype=jnp.bfloat16)
    pshapes = jax.eval_shape(
        lambda: build_model(cfg, opts).init(jax.random.PRNGKey(0)))
    return pshapes, sharding.param_specs(pshapes, mesh, fsdp_axes=fsdp)


@pytest.mark.parametrize("arch", ["qwen2_1_5b", "mamba2_2_7b",
                                  "qwen3_moe_30b_a3b", "jamba_v0_1_52b",
                                  "whisper_tiny"])
def test_no_duplicate_axes_in_param_specs(arch):
    pshapes, pspecs = _specs_for(arch, fsdp="data")
    for spec in jax.tree.leaves(pspecs,
                                is_leaf=lambda x: isinstance(x, P)):
        names = list(_axis_names(spec))
        assert len(names) == len(set(names)), spec


@pytest.mark.parametrize("arch", ["qwen2_1_5b", "dbrx_132b"])
def test_zero1_never_duplicates_fsdp(arch):
    pshapes, pspecs = _specs_for(arch, fsdp="data")
    ostate = jax.eval_shape(optlib.init, pshapes)
    ospecs = sharding.zero1_specs(ostate, optlib.state_specs(pspecs), MESH)
    for spec in jax.tree.leaves(ospecs,
                                is_leaf=lambda x: isinstance(x, P)):
        names = list(_axis_names(spec))
        assert len(names) == len(set(names)), spec


def test_spec_dims_divide_mesh():
    """On the production mesh sizes (16, 16), every sharded dim divides."""
    # fake mesh-shape checks without building 256 devices: use the rule's
    # own divisibility helper against a mesh-like object
    class FakeMesh:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")
    fm = FakeMesh()
    cfg = get_config("mistral_large_123b")
    opts = ModelOptions(dtype=jnp.bfloat16)
    pshapes = jax.eval_shape(
        lambda: build_model(cfg, opts).init(jax.random.PRNGKey(0)))

    def f(path, leaf):
        names = tuple(getattr(k, "key", str(k)) for k in path)
        spec = sharding.param_spec(names, leaf.shape, fm,
                                   fsdp_axes="data")
        for i, s in enumerate(spec):
            if s is None:
                continue
            axes = s if isinstance(s, tuple) else (s,)
            size = 1
            for a in axes:
                size *= fm.shape[a]
            assert leaf.shape[i] % size == 0, (names, leaf.shape, spec)
        return spec

    jax.tree_util.tree_map_with_path(f, pshapes)


def test_cache_specs_long_context_sequence_sharded():
    class FakeMesh:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")
    cfg = smoke_config(get_config("jamba_v0_1_52b"))
    # synthetic KV leaf: (L, B=1, S, KH=8, hd) — batch unshardable,
    # kv-heads don't divide 16 ⇒ sequence must shard
    leaf = jax.ShapeDtypeStruct((4, 1, 8192, 8, 64), jnp.bfloat16)
    specs = sharding.cache_specs({"k": leaf}, FakeMesh(), ("data",),
                                 seq_axis="data")
    spec = specs["k"]
    assert spec[2] is not None          # sequence sharded
    assert spec[3] is None              # kv heads replicated


def test_batch_specs_shard_dim0():
    class FakeMesh:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")
    batch = {"tokens": jax.ShapeDtypeStruct((256, 128), jnp.int32),
             "odd": jax.ShapeDtypeStruct((7, 128), jnp.int32)}
    specs = sharding.batch_specs(batch, FakeMesh(), ("data",))
    assert specs["tokens"][0] == ("data",) or specs["tokens"][0] == "data"
    assert specs["odd"][0] is None      # 7 doesn't divide 16
