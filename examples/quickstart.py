"""Quickstart: model a hybrid distributed training strategy with DistSim.

One API surface: ``sim.simulate()`` is the zero-noise prediction,
``sim.simulate(seeds=...)`` the replay oracle; both return a
``SimBatch`` (``.result()`` unwraps a single lane).

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.configs.base import get_config
from repro.core import (A40_CLUSTER, AnalyticalProvider, DistSim, Strategy,
                        batch_time_error)

cfg = get_config("bert_large")
provider = AnalyticalProvider(A40_CLUSTER)

# "2M2P4D": tensor-parallel 2, pipeline 2, data-parallel 4 (16 GPUs),
# 4 microbatches, Dapple (1F1B) schedule
strat = Strategy(mp=2, pp=2, dp=4, microbatches=4, schedule="1f1b")
sim = DistSim(cfg, strat, global_batch=16, seq=512, provider=provider)

pred = sim.simulate().result()
print(f"strategy          : {strat.label()} x{strat.microbatches} micro")
print(f"predicted batch   : {pred.batch_time*1e3:.2f} ms "
      f"({pred.throughput_iters:.2f} it/s, "
      f"{pred.throughput_tokens/1e6:.2f} Mtok/s)")
print(f"pipeline bubbles  : {pred.bubble_fraction*100:.1f}% idle")

# per-device utilization
util = pred.utilization
print("device utilization:",
      " ".join(f"{d}:{u*100:.0f}%" for d, u in sorted(util.items())[:8]),
      "...")

# the replay oracle ("actual run" stand-in) confirms the prediction
act = sim.simulate(seeds=0).result()
err = batch_time_error(pred.timeline, act.timeline)
print(f"replay batch      : {act.batch_time*1e3:.2f} ms "
      f"(prediction error {err*100:.2f}%)")

# profiling cost (paper Table 3)
rep = sim.profiling_report()
print(f"profiling         : {rep['unique_events']} unique events vs "
      f"{rep['total_instances']} instances "
      f"→ {rep['relative_scale']*100:.1f}% of direct-profiling cost")
