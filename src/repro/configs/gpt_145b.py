"""GPT-145B — the paper's large-scale generalization model (§5.5, Fig. 11).

Megatron-LM 145B configuration: 80L d_model=12288 96H d_ff=49152,
modeled with "8M16P1D" on 128 devices in the paper.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="gpt_145b",
    family="dense",
    n_layers=80,
    d_model=12288,
    n_heads=96,
    n_kv_heads=96,
    d_ff=49152,
    vocab=51200,
    mlp_gelu=True,
    shapes=("train_4k",),
    source="Megatron-LM SC'21 145B (paper §5.5)",
))
