"""Search-engine tests: cache-hit accounting, pruning soundness,
naive-vs-cached equivalence (paper §6 / Table 2 workflow)."""
import dataclasses
import json
import math

import pytest

from repro.configs.base import get_config, smoke_config
from repro.core import (A40_CLUSTER, V5E_POD, AnalyticalProvider, DistSim,
                        get_cluster, grid_search)
from repro.core.events import Event
from repro.search import (ProfileCache, SearchEngine, enumerate_candidates,
                          format_report, pareto_frontier, search_report,
                          work_lower_bound)

CFG = smoke_config(get_config("gpt2_345m"))
GRID = dict(microbatches=(1, 2, 4, 8), schedules=("1f1b", "gpipe"))


def _engine(**kw):
    defaults = dict(clusters=A40_CLUSTER, prune=False, check_memory=False)
    defaults.update(kw)
    return SearchEngine(CFG, **defaults)


@pytest.mark.search
def test_cached_matches_naive_on_64_device_grid():
    """Acceptance: the shared profile cache does ≥5x fewer provider cost
    evaluations than per-candidate profiling, with identical results."""
    naive = _engine(share_cache=False).search(64, 64, 128, **GRID)
    cached = _engine(share_cache=True).search(64, 64, 128, **GRID)

    assert naive.stats.candidates == cached.stats.candidates > 100
    assert cached.stats.provider_evaluations > 0
    assert naive.stats.provider_evaluations \
        >= 5 * cached.stats.provider_evaluations

    # identical best strategy and identical full ranking
    assert cached.best().strategy == naive.best().strategy
    assert [e.strategy for e in cached.entries] \
        == [e.strategy for e in naive.entries]
    for a, b in zip(naive.entries, cached.entries):
        assert math.isclose(a.batch_time, b.batch_time, rel_tol=1e-12)


@pytest.mark.search
def test_cache_hit_accounting_repeat_search_profiles_nothing():
    """Second search over the same grid hits the cache for every event:
    0 new profiler evaluations."""
    eng = _engine(share_cache=True)
    first = eng.search(16, 16, 128, **GRID)
    assert first.stats.provider_evaluations > 0
    again = eng.search(16, 16, 128, **GRID)
    assert again.stats.provider_evaluations == 0
    # the mega-batch lane memoizes engines AND the compiled array
    # program, so a warm repeat search makes no provider queries at all;
    # the per-cell lane still shows the cache-hit traffic
    assert again.stats.cache_hits == 0
    seq = _engine(share_cache=True, megabatch=False)
    seq.search(16, 16, 128, **GRID)
    seq_again = seq.search(16, 16, 128, **GRID)
    assert seq_again.stats.provider_evaluations == 0
    assert seq_again.stats.cache_hits > 0
    # a new schedule reuses the event universe too (schedules reorder
    # events, they don't create new ones)
    sched = eng.search(16, 16, 128, microbatches=(1, 2, 4, 8),
                       schedules=("interleaved",))
    assert sched.stats.provider_evaluations == 0


def test_work_lower_bound_is_sound():
    """The per-device serial-work bound never exceeds the simulated
    batch time."""
    provider = AnalyticalProvider(A40_CLUSTER)
    for cand in enumerate_candidates(16, 16, **GRID):
        sim = DistSim(CFG, cand.strategy, 16, 128, provider)
        positions = sim.positions()
        lb = work_lower_bound(positions, cand.strategy, provider)
        bt = sim.simulate(positions=positions).batch_time
        assert lb <= bt * (1 + 1e-9), cand.label()


def test_pruning_soundness_no_pruned_candidate_beats_best():
    pruned = _engine(prune=True).search(16, 16, 128, **GRID)
    full = _engine(prune=False).search(16, 16, 128, **GRID)
    assert pruned.stats.pruned_bound > 0
    best = pruned.best()
    # pruning never changes the winner
    assert best.strategy == full.best().strategy
    # every pruned candidate, fully simulated, is no better than best
    provider = AnalyticalProvider(A40_CLUSTER)
    for e in pruned.entries:
        if e.pruned:
            bt = DistSim(CFG, e.strategy, 16, 128,
                         provider).simulate().batch_time
            assert bt >= best.batch_time * (1 - 1e-9)
            assert bt >= e.batch_time * (1 - 1e-9)   # entry holds a LB


def test_pruning_sound_under_replay_oracle():
    """Pruning soundness against the validation metrics: even in a
    replayed "actual run", a pruned candidate can undercut its recorded
    bound — and hence the returned best — by at most the paper's
    batch-time error budget (repro.validate.Thresholds)."""
    from repro.validate import Thresholds, compare_timelines

    res = _engine(prune=True).search(16, 16, 128, **GRID)
    assert res.stats.pruned_bound > 0
    best = res.best()
    thr = Thresholds()
    provider = AnalyticalProvider(A40_CLUSTER)
    for e in res.entries:
        if not e.pruned:
            continue
        sim = DistSim(CFG, e.strategy, 16, 128, provider)
        pred = sim.simulate().result()
        act = sim.simulate(seeds=(0,)).result()
        m = compare_timelines(pred.timeline, act.timeline)
        # the oracle itself stays within the validation gate
        assert m.batch_time_error <= thr.batch_time, e.strategy.label()
        # oracle time ≥ (bound | best) minus the error budget
        assert act.batch_time >= e.batch_time * (1 - thr.batch_time)
        assert act.batch_time >= best.batch_time * (1 - thr.batch_time)


def test_memory_pruning_marks_oom_infeasible():
    tiny_chip = dataclasses.replace(A40_CLUSTER.chip, hbm_bytes=1e4)
    tiny = dataclasses.replace(A40_CLUSTER, name="tiny", chip=tiny_chip)
    res = SearchEngine(CFG, clusters=tiny, prune=False,
                       check_memory=True).search(4, 8, 128)
    assert res.stats.pruned_memory == res.stats.candidates
    assert res.stats.evaluated == 0
    assert all(not e.feasible and e.reason == "OOM" for e in res.entries)


def test_multi_cluster_search_and_pareto():
    res = SearchEngine(CFG, clusters=[A40_CLUSTER, V5E_POD],
                       check_memory=True).search(16, 16, 128, **GRID)
    assert set(res.by_cluster) == {"a40-cluster", "v5e-pod"}
    assert res.best("a40-cluster") is not None
    assert res.best("v5e-pod") is not None
    assert res.pareto
    ranking = res.ranking()
    # global best is never dominated
    assert any(e.strategy == ranking[0].strategy
               and e.cluster == ranking[0].cluster for e in res.pareto)
    # frontier members are mutually non-dominated (fixpoint)
    assert pareto_frontier(res.pareto) == res.pareto


def test_search_report_json_and_format():
    res = _engine(prune=True).search(16, 16, 128, **GRID)
    rep = search_report(res, top=5)
    json.dumps(rep)                       # serializable
    assert rep["best"]["rank"] == 1
    assert len(rep["ranking"]) <= 5
    assert rep["search"]["candidates"] == res.stats.candidates
    text = format_report(rep)
    assert rep["best"]["strategy"] in text
    assert "Pareto" in text or not rep["pareto"]


def test_grid_search_compat_delegates_to_engine():
    provider = AnalyticalProvider(A40_CLUSTER)
    with pytest.warns(DeprecationWarning, match="grid_search"):
        entries = grid_search(CFG, 16, 16, 128, provider=provider)
    assert entries == sorted(entries, key=lambda e: e.batch_time)
    assert all(e.feasible and not e.pruned for e in entries)
    best = _engine(share_cache=True).search(16, 16, 128).best()
    assert entries[0].strategy == best.strategy


def test_event_identity_is_structural():
    """Unique-event signature: labels don't split the cache."""
    a = Event(kind="p2p", name="p2p:f:pos0", nbytes=1e6, scope="intra")
    b = Event(kind="p2p", name="p2p:b:pos7", nbytes=1e6, scope="intra")
    assert a == b and hash(a) == hash(b)
    provider = AnalyticalProvider(A40_CLUSTER)
    provider.time(a)
    provider.time(b)
    assert provider.stats.evaluations == 1
    assert provider.stats.hits == 1


def test_profile_cache_snapshot_and_registry():
    cache = ProfileCache.for_clusters([A40_CLUSTER, V5E_POD])
    assert get_cluster("a40-cluster") is A40_CLUSTER
    with pytest.raises(ValueError):
        get_cluster("nope")
    snap = cache.snapshot()
    assert snap["evaluations"] == 0 and snap["unique_events"] == 0
    cache.provider(A40_CLUSTER).time(
        Event(kind="p2p", name="x", nbytes=1e3))
    assert cache.snapshot()["unique_events"] == 1


# --------------------------------------------------------------------------
# mega-batch vectorized predict (PR: one array call per cluster)
# --------------------------------------------------------------------------

@pytest.mark.search
def test_megabatch_bit_identical_on_64_device_grid():
    """Differential oracle: the compiled array program scores every
    non-OOM candidate of the 64-device smoke grid (ragged task counts,
    all four schedules) bit-identically to per-engine run()."""
    from repro.core.megabatch import MegaBatch
    from repro.search.space import enumerate_candidates

    grid = dict(microbatches=(1, 2, 4, 8),
                schedules=("1f1b", "gpipe", "interleaved", "pipedream"))
    eng = _engine(share_cache=True)
    cluster = eng.clusters[0]
    bcache = eng.cache.build_cache(cluster)
    engines = [bcache.engine_for_cfg(CFG, c.strategy, 64, 128)
               for c in enumerate_candidates(64, 64, **grid)]
    assert len(engines) > 100
    assert len({e.total_tasks for e in engines}) > 3    # ragged
    pred = MegaBatch(engines).predict("numpy")
    for i, e in enumerate(engines):
        assert float(pred.batch_times[i]) == e.run().batch_time, \
            e.strat.label()


@pytest.mark.search
@pytest.mark.parametrize("prune", [False, True])
def test_megabatch_search_identical_to_per_cell(prune):
    """SearchEngine(megabatch=True) reproduces the sequential path
    entry-for-entry: same order, bit-identical batch times, identical
    prune decisions and accounting."""
    seq = _engine(prune=prune, check_memory=True,
                  megabatch=False).search(64, 64, 128, **GRID)
    mega = _engine(prune=prune, check_memory=True,
                   megabatch=True).search(64, 64, 128, **GRID)
    assert mega.stats.megabatch_lanes > 0
    assert [e.strategy for e in seq.entries] \
        == [e.strategy for e in mega.entries]
    for a, b in zip(seq.entries, mega.entries):
        assert a.batch_time == b.batch_time          # bit-identical
        assert (a.pruned, a.feasible, a.reason) \
            == (b.pruned, b.feasible, b.reason)
        assert a.profile_time_s == b.profile_time_s
    s, m = seq.stats, mega.stats
    assert (s.candidates, s.evaluated, s.pruned_memory, s.pruned_bound) \
        == (m.candidates, m.evaluated, m.pruned_memory, m.pruned_bound)


def test_cluster_spec_round_trips_and_report_serializes():
    """ClusterSpec.to_dict/from_dict round-trip (Strategy-style), and
    search reports carry full, JSON-serializable cluster specs."""
    from repro.core import ClusterSpec

    spec = ClusterSpec.from_dict(A40_CLUSTER.to_dict())
    assert spec == A40_CLUSTER
    assert spec.chip == A40_CLUSTER.chip     # nested dataclass revived
    res = _engine().search(16, 16, 128, **GRID)
    rep = search_report(res)
    assert set(rep["cluster_specs"]) == {A40_CLUSTER.name}
    dumped = json.dumps(rep["cluster_specs"])
    revived = ClusterSpec.from_dict(
        json.loads(dumped)[A40_CLUSTER.name])
    assert revived == A40_CLUSTER
    assert rep["search"]["megabatch_lanes"] > 0
