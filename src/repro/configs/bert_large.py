"""BERT-Large — paper evaluation model (Fig. 3/8/9/10). [arXiv:1810.04805]

24L d_model=1024 16H d_ff=4096 vocab=30522. Encoder-only (no decode shapes).
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="bert_large",
    family="dense",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=30522,
    qkv_bias=True,
    mlp_gelu=True,
    shapes=("train_4k",),
    source="arXiv:1810.04805 (paper eval model)",
))
