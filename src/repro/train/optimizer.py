"""AdamW in pure JAX (no optax dependency), with fp32 master state.

State layout mirrors the parameter pytree (two moment trees + step),
so the same sharding rules apply; ZeRO-1 sharding of the moments over
the data axis is selected in ``repro.parallel.sharding``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def lr_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup → cosine decay to min_lr_frac."""
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def init(params: Any) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree.map(jnp.copy, zeros))


def state_specs(pspecs: Any) -> AdamWState:
    """Sharding specs for AdamWState given the parameter specs."""
    from jax.sharding import PartitionSpec as P
    return AdamWState(step=P(), mu=pspecs, nu=pspecs)


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def update(cfg: AdamWConfig, params: Any, grads: Any,
           state: AdamWState) -> tuple:
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state.step + 1
    lr = lr_schedule(cfg, state.step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return newp, m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.mu)
    flat_v = tdef.flatten_up_to(state.nu)
    new = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([n[0] for n in new])
    new_m = tdef.unflatten([n[1] for n in new])
    new_v = tdef.unflatten([n[2] for n in new])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(step=step, mu=new_m, nu=new_v), metrics
