"""Top-contributor diagnostics over HLO text — the §Perf profiling tool.

    PYTHONPATH=src python -m repro.core.hlo_diag <hlo.txt> [bytes|coll]

Reuses hlo_stats' exact charging rules but attributes per-instruction,
multiplied by loop trip counts, sorted by total contribution.
"""
from __future__ import annotations

import re
import sys
from typing import List, Tuple

from repro.core import roofline as R


def _trips(comps, entry):
    def trip_count(cond):
        consts = [int(c) for l in comps.get(cond, ())
                  for c in R._CONST_RE.findall(l)]
        return max(consts) if consts else 1

    trips = {entry: 1}
    stack = [entry]
    while stack:
        n0 = stack.pop()
        for line in comps.get(n0, ()):
            wm = R._WHILE_RE.search(line)
            if wm:
                cond = wm.group(1) or wm.group(4)
                body = wm.group(2) or wm.group(3)
                t = trips[n0] * (trip_count(cond) if cond else 1)
                if trips.get(body, 0) < t:
                    trips[body] = t
                    stack.append(body)
            else:
                om = R._OPCODE_RE.search(line)
                if om and om.group(1) in ("fusion", "call", "custom-call",
                                          "conditional"):
                    for cal in R._CALL_RE.findall(line):
                        if trips.get(cal, 0) < trips[n0]:
                            trips[cal] = trips[n0]
                            stack.append(cal)
    return trips


def top_bytes(hlo: str, n: int = 20) -> List[Tuple]:
    comps, entry = R._split_computations(hlo)
    shapes = {}
    internal = {}
    for cname, lines in comps.items():
        internal[cname] = set()
        for l in lines:
            m = R._RESULT_RE.match(l)
            if m:
                shapes[m.group(1)] = (m.group(2), m.group(3))
                om = R._OPCODE_RE.search(l)
                if om and om.group(1) not in ("parameter",
                                              "get-tuple-element",
                                              "constant"):
                    internal[cname].add(m.group(1))

    def nbytes_of(name):
        sh = shapes.get(name)
        if sh is None or sh[0] not in R._DTYPE_BYTES:
            return 0.0
        return R._shape_bytes(sh[0], sh[1])

    trips = _trips(comps, entry)
    VMEM = 128 * 2 ** 20
    rows = []
    for cname, lines in comps.items():
        t = trips.get(cname, 0)
        if not t:
            continue
        own = internal[cname]
        for line in lines:
            rm = R._RESULT_RE.match(line)
            om = R._OPCODE_RE.search(line)
            opcode = om.group(1) if om else ""
            if (not rm or not opcode or opcode in R._FREE_OPS
                    or opcode in R._EW_OPS):
                continue
            res_b = (R._shape_bytes(rm.group(2), rm.group(3))
                     if rm.group(2) in R._DTYPE_BYTES else 0.0)
            idx = line.find(opcode + "(")
            op_names = (R._OPERAND_RE.findall(
                line[idx + len(opcode) + 1:].split(")")[0])
                if idx >= 0 else [])
            in_loop = t > 4
            if in_loop:
                op_bytes = [0.0 if (nm in own and nbytes_of(nm) <= VMEM)
                            else nbytes_of(nm) for nm in op_names]
                if res_b <= VMEM and not line.startswith("ROOT"):
                    res_b = 0.0
            else:
                op_bytes = [nbytes_of(nm) for nm in op_names]
            iname = rm.group(1)
            if (opcode in ("dynamic-update-slice", "scatter")
                    or "dynamic-update-slice" in iname
                    or "scatter" in iname):
                b = 2.0 * sum(sorted(op_bytes)[:-1])
            elif (opcode in ("dynamic-slice", "slice", "gather")
                  or "dynamic-slice" in iname or "gather_fusion" in iname):
                b = 2.0 * res_b
            else:
                if opcode == "fusion":
                    callees = R._CALL_RE.findall(line)
                    body = comps.get(callees[0], []) if callees else []
                    if any("dynamic-slice" in bl for bl in body):
                        op_bytes = [min(ob, max(res_b, 1.0))
                                    for ob in op_bytes]
                b = res_b + sum(op_bytes)
            if b * t > 0:
                m = re.search(r'op_name="([^"]*)"', line)
                rows.append((b * t, t, b, opcode,
                             (m.group(1) if m else iname)[-80:]))
    rows.sort(reverse=True)
    return rows[:n]


def main():
    path = sys.argv[1]
    hlo = open(path).read()
    rows = top_bytes(hlo)
    tot = sum(r[0] for r in rows)
    print(f"top-{len(rows)} bytes = {tot/1e12:.2f} TB "
          f"(t_mem {tot/819e9:.1f}s)")
    for r in rows:
        print(f"{r[0]/1e9:8.1f}GB trips={r[1]:5d} per={r[2]/1e9:6.2f}GB "
              f"{r[3]:14s} {r[4]}")


if __name__ == "__main__":
    main()
