"""Structured validation reports: JSON round-trip + terminal rendering.

``dump`` lowers a :class:`SweepResult` to a JSON-ready dict and
``load`` reconstructs an equal object (``load(dump(r)) == r``), which
is what lets goldens under ``tests/goldens/`` and the CI artifact
``validation_report.json`` share one format. ``format_validation_report``
renders the per-cell pass/fail table via the shared
:func:`repro.search.report.format_table`.
"""
from __future__ import annotations

import json
import math
from typing import Dict, Union

from repro.core.events import Strategy
from repro.core.scenario import scenario_from_dict
from repro.search.report import format_table
from repro.validate.metrics import CellMetrics
from repro.validate.sweep import (CellResult, SweepResult, Thresholds,
                                  ValidationCell)

SCHEMA_VERSION = 1


def _enc_f(v: float):
    """Non-finite floats become strings ('inf', '-inf', 'nan') so the
    emitted file stays RFC-8259 JSON — a degenerate-replay report with
    infinite error must still parse in jq/JS, that's exactly the case
    it exists to surface."""
    return v if math.isfinite(v) else repr(v)


def _dec_f(v) -> float:
    return float(v) if isinstance(v, str) else v


def _enc_metrics(d: Dict[str, float]) -> Dict:
    return {k: _enc_f(v) for k, v in d.items()}


def _dec_metrics(d: Dict) -> Dict[str, float]:
    return {k: _dec_f(v) for k, v in d.items()}


def _cell_dict(c: CellResult) -> Dict:
    d = {
        "label": c.cell.label(),
        "arch": c.cell.arch,
        "smoke": c.cell.smoke,
        "xfail": c.cell.xfail,
        "strategy": c.cell.strategy.to_dict(),
        "global_batch": c.cell.global_batch,
        "seq": c.cell.seq,
        "seeds": list(c.seeds),
        "pred_batch_time": _enc_f(c.pred_batch_time),
        "replay_batch_times": [_enc_f(t) for t in c.replay_batch_times],
        "metrics": _enc_metrics(c.metrics.to_dict()),
        "per_seed": [_enc_metrics(m.to_dict()) for m in c.per_seed],
        "violations": list(c.violations),
        "passed": c.passed,
    }
    # scenario key only for serving cells: training reports/goldens
    # stay byte-identical to the pre-scenario schema
    if not c.cell.scenario.is_train:
        d["scenario"] = c.cell.scenario.to_dict()
    return d


def _cell_from_dict(d: Dict) -> CellResult:
    cell = ValidationCell(
        arch=d["arch"], strategy=Strategy.from_dict(d["strategy"]),
        global_batch=d["global_batch"], seq=d["seq"],
        smoke=d["smoke"], xfail=d["xfail"],
        scenario=scenario_from_dict(d.get("scenario")))
    return CellResult(
        cell=cell,
        metrics=CellMetrics.from_dict(_dec_metrics(d["metrics"])),
        per_seed=[CellMetrics.from_dict(_dec_metrics(m))
                  for m in d["per_seed"]],
        seeds=list(d["seeds"]),
        pred_batch_time=_dec_f(d["pred_batch_time"]),
        replay_batch_times=[_dec_f(t) for t in d["replay_batch_times"]],
        violations=list(d["violations"]))


def dump(result: SweepResult) -> Dict:
    """JSON-ready dict (lists only, no tuples — survives json round-trip)."""
    return {
        "schema": SCHEMA_VERSION,
        "cluster": result.cluster,
        "seeds": list(result.seeds),
        "jitter_sigma": result.jitter_sigma,
        "thresholds": result.thresholds.to_dict(),
        "cells": [_cell_dict(c) for c in result.cells],
        "passed": result.passed,
        "n_cells": len(result.cells),
        "n_failures": len(result.failures),
        "n_xpasses": len(result.xpasses),
    }


def load(obj: Union[Dict, str]) -> SweepResult:
    """Inverse of :func:`dump`; accepts the dict or its JSON string.
    Rejects other schema versions instead of default-filling fields —
    a stale golden/artifact must error, not silently mis-load."""
    d = json.loads(obj) if isinstance(obj, str) else obj
    if d.get("schema") != SCHEMA_VERSION:
        raise ValueError(
            f"validation report schema {d.get('schema')!r} != "
            f"{SCHEMA_VERSION} — regenerate with "
            f"benchmarks/bench_validate.py")
    return SweepResult(
        cells=[_cell_from_dict(c) for c in d["cells"]],
        thresholds=Thresholds.from_dict(d["thresholds"]),
        cluster=d["cluster"], seeds=list(d["seeds"]),
        jitter_sigma=d["jitter_sigma"])


def dumps(result: SweepResult, indent: int = 2) -> str:
    # allow_nan=False: hard guarantee the artifact is strict JSON
    # (non-finite floats are string-encoded by dump)
    return json.dumps(dump(result), indent=indent, sort_keys=True,
                      allow_nan=False)


def save(result: SweepResult, path: str) -> None:
    with open(path, "w") as f:
        f.write(dumps(result) + "\n")


def load_path(path: str) -> SweepResult:
    with open(path) as f:
        return load(json.load(f))


def format_validation_report(report: Union[Dict, SweepResult]) -> str:
    """Terminal rendering: threshold header + per-cell metric table."""
    d = dump(report) if isinstance(report, SweepResult) else report
    thr = d["thresholds"]
    lines = [
        f"validation sweep on {d['cluster']}: {d['n_cells']} cells, "
        f"seeds={d['seeds']}, jitter={100 * d['jitter_sigma']:.1f}%",
        f"thresholds: batch_time<{100 * thr['batch_time']:.0f}% "
        f"(worst seed <{100 * thr['batch_time_worst']:.0f}%) "
        f"activity<{100 * thr['activity']:.0f}% "
        f"stage<{100 * thr['stage']:.0f}% "
        f"utilization<{100 * thr['utilization']:.0f}% (paper §5: <4%/<5%)",
        "",
    ]
    rows = []
    for c in d["cells"]:
        m = _dec_metrics(c["metrics"])    # dump() string-encodes inf/nan
        status = "PASS" if c["passed"] else "FAIL"
        if c["xfail"]:
            status = "XPASS" if c["passed"] else "xfail"
        rows.append([
            c["label"], f"{100 * m['batch_time_error']:.2f}",
            f"{100 * m['worst_batch_time_error']:.2f}",
            f"{100 * m['activity_error_max']:.2f}",
            f"{100 * m['stage_error_max']:.2f}",
            f"{100 * m['utilization_delta_max']:.2f}",
            status + ("" if c["passed"] else
                      f" [{','.join(c['violations'])}]"),
        ])
    lines.extend(format_table(
        ["cell", "bt%", "bt_worst%", "act%", "stage%", "util%", "status"],
        rows, aligns=("<", ">", ">", ">", ">", ">", "<")))
    verdict = "PASSED" if d["passed"] else "FAILED"
    n_ok = sum(1 for c in d["cells"] if c["passed"])
    n_xfail = sum(1 for c in d["cells"] if c["xfail"] and not c["passed"])
    lines.append("")
    lines.append(f"{verdict}: {n_ok}/{d['n_cells']} cells within "
                 f"thresholds"
                 + (f", {n_xfail} xfail" if n_xfail else "")
                 + (f", {d['n_xpasses']} xpass" if d["n_xpasses"] else ""))
    return "\n".join(lines)
