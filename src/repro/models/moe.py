"""Mixture-of-Experts FFN, static-shape, expert-parallel friendly.

Two implementations:

* ``gather`` (default): per-expert top-capacity token selection via argsort.
  All shapes static; with experts sharded over the ``model`` axis this
  lowers to all-to-all style collectives. O(T·E·logT) routing work, but the
  expert GEMMs dominate. Tokens over capacity are dropped (standard
  capacity-factor semantics); dropped tokens pass through the residual.

* ``dense_dispatch``: Mesh-TF style one-hot dispatch einsum. Exact same
  math, used as the small-scale reference in tests (memory O(T·E·C)).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig


def moe_params_shape(d_model: int, mcfg: MoEConfig, mlp_gelu: bool = False):
    e, f = mcfg.n_experts, mcfg.d_ff_expert
    return {
        "router": (d_model, e),
        "w_gate": (e, d_model, f),
        "w_up": (e, d_model, f),
        "w_down": (e, f, d_model),
    }


def capacity(n_tokens: int, mcfg: MoEConfig) -> int:
    c = int(n_tokens * mcfg.top_k * mcfg.capacity_factor / mcfg.n_experts)
    c = max(8, (c + 7) // 8 * 8)                     # pad to 8 for layout
    return min(n_tokens, c)


def dropless_capacity_factor(mcfg: MoEConfig) -> float:
    """A capacity factor at which ``capacity(t, mcfg) == t`` for every
    t — no token can ever be dropped. Nominally n_experts / top_k; a
    tiny relative cushion keeps ``int(t * top_k * cf / n_experts)``
    from truncating below t when n_experts isn't divisible by top_k.

    Capacity-factor routing is NON-CAUSAL along the sequence: the
    per-expert argsort competes ALL tokens (including future positions)
    for cap slots, so whether token t survives depends on tokens after
    it. Batched (teacher-forced) forward therefore cannot be reproduced
    by token-by-token decode whenever any expert oversubscribes — decode
    sees a different competitor set by construction. With a dropless
    capacity the competition never binds and the two paths agree exactly
    (see tests/test_decode_consistency.py).
    """
    return mcfg.n_experts / mcfg.top_k * (1.0 + 1e-6)


def router_probs(x2d: jax.Array, router_w: jax.Array, mcfg: MoEConfig):
    """x2d: (T, d) → (T, E) softmax probs (f32), top-k indices/weights."""
    logits = jnp.einsum("td,de->te", x2d.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, mcfg.top_k)     # (T,k)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)
    return probs, topi, topw


def moe_gather(x: jax.Array, params, mcfg: MoEConfig):
    """x: (B,S,d) → (B,S,d). Static-shape gather/scatter MoE."""
    b, s, d = x.shape
    t = b * s
    x2d = x.reshape(t, d)
    cap = capacity(t, mcfg)
    e = mcfg.n_experts

    probs, topi, topw = router_probs(x2d, params["router"], mcfg)

    # score[t, e] = gate weight if expert e selected for token t else -inf
    sel = jnp.full((t, e), -jnp.inf, jnp.float32)
    tok_idx = jnp.arange(t)[:, None]                  # (T,1)
    sel = sel.at[tok_idx, topi].set(topw)

    # per-expert top-capacity token ids (argsort desc over tokens).
    # stop_gradient: routing is a discrete decision (and this jax
    # build's sort-JVP rule is broken under SPMD partitioning).
    order = jnp.argsort(-jax.lax.stop_gradient(sel), axis=0)   # (T,E)
    chosen = order[:cap].T                            # (E,C) token ids
    gatew = jnp.take_along_axis(sel, chosen.T, axis=0).T  # (E,C)
    live = jnp.isfinite(gatew)
    gatew = jnp.where(live, gatew, 0.0)

    xe = x2d[chosen.reshape(-1)].reshape(e, cap, d)   # (E,C,d) gather

    # expert GEMMs (batched over experts; shard E over `model` axis)
    g = jnp.einsum("ecd,edf->ecf", xe, params["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", xe, params["w_up"])
    y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, params["w_down"])
    y = y * gatew[..., None].astype(y.dtype)

    # scatter-add back to token order
    out = jnp.zeros((t, d), y.dtype)
    out = out.at[chosen.reshape(-1)].add(
        (y * live[..., None]).reshape(e * cap, d))
    return out.reshape(b, s, d), probs


def moe_dense_dispatch(x: jax.Array, params, mcfg: MoEConfig):
    """Reference Mesh-TF one-hot dispatch (tests only; O(T·E·C) memory)."""
    b, s, d = x.shape
    t = b * s
    x2d = x.reshape(t, d)
    cap = capacity(t, mcfg)
    e = mcfg.n_experts

    probs, topi, topw = router_probs(x2d, params["router"], mcfg)
    sel = jnp.full((t, e), -jnp.inf, jnp.float32)
    sel = sel.at[jnp.arange(t)[:, None], topi].set(topw)
    order = jnp.argsort(-jax.lax.stop_gradient(sel), axis=0)   # (T,E)
    # rank of token within expert queue
    rank = jnp.zeros((t, e), jnp.int32).at[order, jnp.arange(e)[None]].set(
        jnp.arange(t, dtype=jnp.int32)[:, None])
    keep = (rank < cap) & jnp.isfinite(sel)
    disp = (jax.nn.one_hot(jnp.where(keep, rank, cap), cap + 1)[..., :cap]
            * keep[..., None])                        # (T,E,C)
    xe = jnp.einsum("tec,td->ecd", disp, x2d)
    g = jnp.einsum("ecd,edf->ecf", xe, params["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", xe, params["w_up"])
    y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, params["w_down"])
    comb = disp * jnp.where(keep, sel, 0.0)[..., None]
    out = jnp.einsum("tec,ecd->td", comb.astype(y.dtype), y)
    return out.reshape(b, s, d), probs


def moe_ffn(x, params, mcfg: MoEConfig, impl: str = "gather", opts=None):
    if impl == "ep_a2a":
        return moe_ep_a2a(x, params, mcfg, opts)   # aux already reduced
    if impl == "gather":
        y, probs = moe_gather(x, params, mcfg)
    elif impl == "dense_dispatch":
        y, probs = moe_dense_dispatch(x, params, mcfg)
    else:
        raise ValueError(impl)
    # load-balancing auxiliary loss (Switch-style)
    me = probs.mean(0)                                # (E,)
    aux = mcfg.n_experts * jnp.sum(me * me)
    return y, aux


# --------------------------------------------------------------------------
# explicit expert parallelism: all-to-all dispatch via shard_map
# --------------------------------------------------------------------------

def moe_ep_a2a(x: jax.Array, params, mcfg: MoEConfig, opts):
    """Expert-parallel MoE with explicit all-to-all (shard_map).

    Under GSPMD auto-partitioning, the token→expert gather and the
    combine scatter lower to all-gather/all-reduce of the FULL global
    token buffer per layer (measured 16+24 GB/device/layer on
    dbrx-132b — EXPERIMENTS.md §Perf B). The production pattern moves
    only the ROUTED tokens: each shard routes its local tokens, sends
    (E, C_e) slots to expert owners with one all-to-all, computes its
    local experts, and reverses the all-to-all — wire bytes
    t_loc·topk·cf·d instead of T_global·d.

    Layout contract: x is (B, S, d) sharded P(dp_axes, ep_axis, None);
    experts are sharded over `ep_axis`. Gradients flow through (the
    transpose of all_to_all is all_to_all).
    """
    import jax
    from jax.sharding import PartitionSpec as P

    ep_axis = opts.ep_axis
    dp_axes = opts.dp_axes
    e = mcfg.n_experts

    mesh = jax.sharding.get_abstract_mesh()
    ep_size = mesh.shape[ep_axis]
    assert e % ep_size == 0, (e, ep_size)
    axis_names = (tuple(dp_axes if isinstance(dp_axes, (tuple, list))
                        else (dp_axes,)) + (ep_axis,))

    x_spec = P(dp_axes, ep_axis, None)
    w_specs = {
        "router": P(),
        "w_gate": P(ep_axis, None, None),
        "w_up": P(ep_axis, None, None),
        "w_down": P(ep_axis, None, None),
    }

    def local(x_loc, params_loc):
        b, s_loc, d = x_loc.shape
        t = b * s_loc
        x2d = x_loc.reshape(t, d)
        cap = capacity(t, mcfg)                     # per-expert C_e
        e_loc = e // ep_size

        probs, topi, topw = router_probs(x2d, params_loc["router"], mcfg)
        sel = jnp.full((t, e), -jnp.inf, jnp.float32)
        sel = sel.at[jnp.arange(t)[:, None], topi].set(topw)
        order = jnp.argsort(-jax.lax.stop_gradient(sel), axis=0)
        chosen = order[:cap].T                      # (E, C)
        gatew = jnp.take_along_axis(sel, chosen.T, axis=0).T
        live = jnp.isfinite(gatew)
        gatew = jnp.where(live, gatew, 0.0)

        send = x2d[chosen.reshape(-1)].reshape(e, cap, d)   # (E, C, d)
        send = send * live[..., None].astype(send.dtype)
        # (E, C, d) → (ep, E_loc, C, d) → a2a → (ep, E_loc, C, d) where
        # dim0 now indexes the SOURCE peer
        send = send.reshape(ep_size, e_loc, cap, d)
        recv = jax.lax.all_to_all(send, ep_axis, split_axis=0,
                                  concat_axis=0, tiled=False)

        # local expert GEMMs over tokens from all peers
        xe = recv.transpose(1, 0, 2, 3).reshape(e_loc, ep_size * cap, d)
        g = jnp.einsum("ecd,edf->ecf", xe, params_loc["w_gate"])
        u = jnp.einsum("ecd,edf->ecf", xe, params_loc["w_up"])
        y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u,
                       params_loc["w_down"])

        # reverse path: back to origin shards, original slot order
        y = y.reshape(e_loc, ep_size, cap, d).transpose(1, 0, 2, 3)
        back = jax.lax.all_to_all(y, ep_axis, split_axis=0,
                                  concat_axis=0, tiled=False)
        back = back.reshape(e, cap, d) * gatew[..., None].astype(y.dtype)

        out = jnp.zeros((t, d), back.dtype)
        out = out.at[chosen.reshape(-1)].add(
            (back * live[..., None]).reshape(e * cap, d))

        me = probs.mean(0)
        aux = mcfg.n_experts * jnp.sum(me * me)
        aux = jax.lax.pmean(aux, axis_names)
        return out.reshape(b, s_loc, d), aux

    fn = jax.shard_map(local, mesh=mesh,
                       in_specs=(x_spec, w_specs),
                       out_specs=(x_spec, P()))
    return fn(x, {k: params[k] for k in w_specs})
