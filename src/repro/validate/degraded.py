"""Degraded-fleet validation matrix: perturbed runs under structural
gates.

The happy-path sweep (:mod:`repro.validate.sweep`) gates predict vs
replay conformance; this module gates the *perturbation axis* — the
spliced straggler/fault timelines of ``DistSim.simulate(perturb=...)``
— on invariants that must hold for ANY cost model:

* segments tile the run exactly (``[0, steps)``, contiguous, in order);
* checkpoint arithmetic (``ckpt_step`` on a ``save_every`` boundary,
  ``lost = at_step - ckpt_step``);
* recovery components non-negative, restore-read strictly positive;
* the surviving grid only shrinks (``dp`` monotone non-increasing) and
  the effective global batch follows it (microbatch held constant);
* pure-straggler runs (all factors >= 1) never finish faster than the
  clean run on the same grid.

``benchmarks/bench_fault.py`` wraps :func:`run_degraded` for CI and
additionally pins the predicted recovery times / post-failure
throughput against goldens (``tests/goldens/validation_degraded.json``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Union

import numpy as np

from repro.configs.base import get_config, smoke_config
from repro.core.costmodel import A40_CLUSTER, ClusterSpec, get_cluster
from repro.core.events import Strategy
from repro.core.perturb import DegradedRun, Fault, Perturbation, Straggler
from repro.core.profiler import AnalyticalProvider, Provider
from repro.core.simulator import DistSim


@dataclasses.dataclass(frozen=True)
class DegradedCell:
    """One matrix point: a model/strategy pair under one perturbation."""
    arch: str
    strategy: Strategy
    perturb: Perturbation
    global_batch: int = 16
    seq: int = 512
    smoke: bool = False
    xfail: str = ""                   # known-bad reason; reported, not gated

    def label(self) -> str:
        arch = self.arch + ("~smoke" if self.smoke else "")
        return f"{arch}/{self.strategy.label()}/{self.perturb.label()}"

    def config(self):
        cfg = get_config(self.arch)
        return smoke_config(cfg) if self.smoke else cfg


@dataclasses.dataclass
class DegradedCellResult:
    cell: DegradedCell
    run: DegradedRun
    baseline_total: np.ndarray        # (S,) clean run on the original grid
    violations: List[str]

    @property
    def passed(self) -> bool:
        return not self.violations

    @property
    def gates(self) -> bool:
        return not self.cell.xfail

    def to_dict(self) -> Dict:
        return {
            "label": self.cell.label(),
            "violations": list(self.violations),
            "baseline_total": [float(t) for t in self.baseline_total],
            "total_times": [float(t) for t in self.run.total_times],
            "recovery_times": [
                [float(t) for t in r.recovery_times]
                for r in self.run.recoveries],
            "post_failure_throughput": [
                float(t) for t in self.run.post_failure_throughput],
            "effective_global_batch": self.run.effective_global_batch,
            "final_strategy": self.run.final_strategy.label(),
            "steps_lost": self.run.steps_lost,
        }


@dataclasses.dataclass
class DegradedReport:
    cells: List[DegradedCellResult]
    cluster: str
    seeds: Optional[List[int]]

    @property
    def failures(self) -> List[DegradedCellResult]:
        return [c for c in self.cells if c.gates and not c.passed]

    @property
    def passed(self) -> bool:
        return not self.failures

    def to_dict(self) -> Dict:
        return {"cluster": self.cluster,
                "seeds": list(self.seeds) if self.seeds else None,
                "cells": [c.to_dict() for c in self.cells]}


# --------------------------------------------------------------------------
# the matrix
# --------------------------------------------------------------------------

def _cell(arch, mp, pp, dp, m, schedule, perturb, gb=16, seq=512,
          smoke=False, zero1=False, xfail="") -> DegradedCell:
    return DegradedCell(
        arch, Strategy(mp=mp, pp=pp, dp=dp, microbatches=m,
                       schedule=schedule, zero1=zero1),
        perturb=perturb, global_batch=gb, seq=seq, smoke=smoke,
        xfail=xfail)


def degraded_matrix() -> List[DegradedCell]:
    """CI-scale degraded-fleet matrix: a straggler ladder (the first
    two cells differ only in ``factor`` — bench_fault gates
    monotonicity across them), a windowed per-replica straggler, and
    fault/recovery cells covering dp shrink, straggler-then-fault,
    ZeRO-1 restore sharding, and a double fault on a pure-DP grid."""
    def slow(f, w=(0, -1)):
        return (Straggler(1, f, w), Straggler(3, f, w))
    return [
        # straggler ladder — pipeline device 1 of BOTH replicas (ranks
        # 1 and 3 of the 1M2P2D flat grid), factors 1.25 then 1.5
        _cell("gpt2_345m", 1, 2, 2, 4, "1f1b",
              Perturbation(stragglers=slow(1.25), steps=8)),
        _cell("gpt2_345m", 1, 2, 2, 4, "1f1b",
              Perturbation(stragglers=slow(1.5), steps=8)),
        # windowed single-rank straggler: per-replica (non-uniform
        # across dp), exercises the segment cuts at the window edges
        _cell("gpt2_345m", 1, 2, 2, 4, "1f1b",
              Perturbation(stragglers=(Straggler(1, 2.0, (2, 6)),),
                           steps=8)),
        # fault at step 6 with checkpoints every 4: restore from step
        # 4, recompute 2 steps, dp 2 -> 1 (mp*pp group kept intact)
        _cell("gpt2_345m", 1, 2, 2, 4, "1f1b",
              Perturbation(faults=(Fault(3, 6, detect_s=0.5),),
                           steps=12, save_every=4)),
        # hybrid mp·pp grid, straggler window then a fault: the
        # post-replan segment must run clean (mitigation (b))
        _cell("bert_large", 2, 2, 2, 4, "1f1b",
              Perturbation(stragglers=(Straggler(2, 1.5, (0, 4)),),
                           faults=(Fault(7, 5),),
                           steps=10, save_every=5, replan_s=2.0)),
        # ZeRO-1: optimizer moments dp-sharded, so the restore read is
        # smaller than the replicated-optimizer equivalent
        _cell("t5_large", 1, 2, 2, 4, "1f1b",
              Perturbation(faults=(Fault(1, 3),), steps=8, save_every=2),
              zero1=True),
        # pure-DP double fault: dp 4 -> 2 -> 2 (power-of-two replan)
        _cell("gpt2_345m", 1, 1, 4, 2, "1f1b",
              Perturbation(faults=(Fault(0, 3), Fault(2, 7,
                                                      detect_s=1.0)),
                           steps=10, save_every=4)),
    ]


# --------------------------------------------------------------------------
# structural gates
# --------------------------------------------------------------------------

def structural_violations(cell: DegradedCell, run: DegradedRun,
                          baseline_total: np.ndarray) -> List[str]:
    """Cost-model-independent invariants of a spliced degraded run."""
    out: List[str] = []
    p = cell.perturb

    # segments tile [0, steps) contiguously and in order
    spans = [(s.start, s.stop) for s in run.segments]
    ok = bool(spans) and spans[0][0] == 0 and spans[-1][1] == p.steps \
        and all(a[1] == b[0] for a, b in zip(spans, spans[1:]))
    if not ok:
        out.append("segment_cover")

    # checkpoint arithmetic
    for r in run.recoveries:
        if (r.ckpt_step % p.save_every or
                r.lost_steps != r.fault.at_step - r.ckpt_step):
            out.append("ckpt_arithmetic")
            break

    # recovery components: all non-negative, restore strictly positive
    for r in run.recoveries:
        durs = {e.kind: e.duration for e in r.events}
        if any(np.any(d < 0) for d in durs.values()) \
                or not np.all(durs["restore"] > 0):
            out.append("recovery_component")
            break

    # the surviving grid only shrinks, and gb follows it
    dp0, dpf = cell.strategy.dp, run.final_strategy.dp
    if dpf > dp0:
        out.append("dp_grew")
    if run.effective_global_batch != \
            (cell.global_batch // dp0) * dpf:
        out.append("effective_gb")

    if not np.all(run.post_failure_throughput > 0):
        out.append("throughput")

    # pure-straggler runs with slowdown factors never beat the clean
    # run (faults excluded: a shrunk grid can legitimately step faster)
    if not p.faults and all(s.factor >= 1.0 for s in p.stragglers):
        if np.any(run.total_times < baseline_total - 1e-12):
            out.append("faster_than_clean")

    return out


# --------------------------------------------------------------------------
# engine
# --------------------------------------------------------------------------

def run_degraded_cell(cell: DegradedCell, provider: Provider,
                      seeds: Union[int, Sequence[int], None] = None,
                      jitter_sigma: float = 0.025) -> DegradedCellResult:
    sim = DistSim(cell.config(), cell.strategy, cell.global_batch,
                  cell.seq, provider)
    run = sim.simulate(perturb=cell.perturb, seeds=seeds,
                       jitter_sigma=jitter_sigma)
    baseline_total = cell.perturb.steps * run.baseline_step_time
    return DegradedCellResult(
        cell=cell, run=run, baseline_total=baseline_total,
        violations=structural_violations(cell, run, baseline_total))


def run_degraded(cells: Optional[Sequence[DegradedCell]] = None,
                 cluster: Union[str, ClusterSpec, None] = None,
                 seeds: Union[int, Sequence[int], None] = None,
                 jitter_sigma: float = 0.025,
                 provider: Optional[Provider] = None) -> DegradedReport:
    """Run the degraded matrix; one shared provider, so the unique-event
    dedup applies across cells exactly as in the happy-path sweep."""
    if isinstance(cluster, str):
        cluster = get_cluster(cluster)
    cells = list(cells) if cells is not None else degraded_matrix()
    if (provider is not None and cluster is not None
            and provider.cluster != cluster):
        raise ValueError(
            f"cluster {cluster.name!r} disagrees with the provider's "
            f"{provider.cluster.name!r}; pass one or the other")
    provider = provider or AnalyticalProvider(cluster or A40_CLUSTER)
    results = [run_degraded_cell(c, provider, seeds, jitter_sigma)
               for c in cells]
    if isinstance(seeds, (int, np.integer)):
        seed_list: Optional[List[int]] = [int(seeds)]
    else:
        seed_list = list(seeds) if seeds is not None else None
    return DegradedReport(cells=results,
                          cluster=provider.cluster.name,
                          seeds=seed_list)


def format_degraded_report(report: DegradedReport) -> str:
    lines = [f"degraded matrix on {report.cluster} "
             f"(seeds={report.seeds or 'predict'})"]
    for c in report.cells:
        run = c.run
        mark = "PASS" if c.passed else \
            f"FAIL[{','.join(c.violations)}]"
        if c.cell.xfail:
            mark += f" (xfail: {c.cell.xfail})"
        t = float(run.total_times[0])
        b = float(c.baseline_total[0])
        extra = ""
        if run.recoveries:
            rec = sum(float(r.recovery_times[0])
                      for r in run.recoveries)
            extra = (f" recovery={rec:.3f}s lost={run.steps_lost} "
                     f"-> {run.final_strategy.label()}"
                     f" gb={run.effective_global_batch}")
        lines.append(f"  {mark:<28} {c.cell.label():<60} "
                     f"total={t:.3f}s clean={b:.3f}s "
                     f"x{t / b:.2f}{extra}")
    n_fail = len(report.failures)
    lines.append(f"{len(report.cells)} cells, {n_fail} failures -> "
                 + ("PASS" if report.passed else "FAIL"))
    return "\n".join(lines)
